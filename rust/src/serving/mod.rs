//! Iteration-level continuous batching for the serve loop (the vLLM /
//! OpenRLHF scheduling discipline in front of the hybrid engine).
//!
//! The fixed-batch serve loop padded every generation with repeated
//! prompts and held all `b` slots until the slowest request finished, so a
//! request arriving mid-generate waited a full `gen_len`-step decode and
//! early-EOS slots burned capacity on dead rows. The [`Scheduler`] here
//! works at *decode-step* granularity instead — each [`Scheduler::step`]:
//!
//! 1. **admits** queued requests into free batch slots (one per-slot
//!    prefill call each; the new sequence's K/V rows overwrite a retired
//!    slot's rows while the other slots' device state is untouched),
//! 2. **samples** one token per live slot from its pending row and
//!    **retires** sequences immediately on EOS or length (the slot frees
//!    this step, refills next step),
//! 3. runs **one fused decode call** that advances every live slot at its
//!    own sequence position.
//!
//! # Per-step host traffic
//!
//! The scheduler is generic over the [`SamplingBackend`] driving it; the
//! backend's [`TrafficClass`] decides both which artifact family the
//! engine executes and what a slot's pending state is:
//!
//! * `HostFullRow` → `decode_slots`, a `[b, vocab]` logits matrix down,
//!   full host-side sampling (repetition penalty available);
//! * `DeviceTopK` greedy → `decode_slots_sampled`, `[b]` token ids down —
//!   O(b) bytes per tick;
//! * `DeviceTopK` stochastic → `decode_slots_sampled`, `[b, k]` candidate
//!   logits+ids down — O(b·k); the host finishes temperature/top-p and
//!   the categorical draw with its seeded RNG.
//! * `DeviceCategorical` → `decode_slots_rng`, `[b]` token ids down even
//!   for STOCHASTIC sampling — the temperature/top-k/top-p categorical
//!   draw runs on device from a counter-based Threefry stream keyed by
//!   each request's `(seed, step)` (`crate::sampling::device`), so host
//!   bytes drop from O(b·k) to O(b) and every request's tokens stay a
//!   pure function of its own seed no matter the admission order, slot
//!   placement, or chunking. The scheduler threads each slot's seed words
//!   and step counter to the engine through [`AdmissionRng`] /
//!   [`DecodeRng`]; a request without an explicit [`Request::seed`] gets
//!   a deterministic per-id default.
//!
//! In every class the sampled token ids land on the host each tick, so
//! EOS/length retirement stays a host decision — sample on device, retire
//! on host.
//!
//! # Fused N-token decode chunks
//!
//! [`Scheduler::set_decode_chunk`] raises the decode dispatch granularity
//! from one token to `N`: each tick issues ONE `decode_chunk{N}` artifact
//! call ([`SlotEngine::decode_slots_chunk`]) that advances every live
//! slot by up to `N` tokens and returns the `[N, b]` emitted ids, so
//! dispatches/token drop ~N× on top of the device-RNG family's O(b)
//! bytes/token. Admission, deadline checks, and retirement generalize to
//! every-`N`-steps boundaries (`step_idx` advances by `N` per tick, so
//! [`FaultPolicy::deadline_steps`] keeps its step units at chunk
//! granularity). On device a per-row latch freezes any row that emits EOS
//! or exhausts its budget mid-chunk — a frozen row re-writes its last
//! live K/V row bit-identically and consumes no further RNG draws — so
//! chunked decode is bit-identical to `N` stepwise ticks including
//! mid-chunk retirement (pinned by the chunk equivalence tests here and
//! the artifact goldens). The chunk slots a frozen row burns are counted
//! in [`SchedStats::chunk_waste_tokens`] and fold into
//! [`SchedStats::bubble_fraction`]. Chunked serving requires the
//! device-RNG backend (the device must draw tokens the host has not seen
//! yet — a host backend cannot interleave its draws into a fused chunk)
//! and, on the hybrid engine, the paged pool; `N = 1` is the legacy
//! stepwise path, bit-compatible with every pre-chunk golden.
//!
//! The engine contract is the [`SlotEngine`] trait so the
//! scheduling policy is unit-testable without artifacts; [`HybridEngine`]
//! implements it over the `prefill_slot` / `decode_slots` (and
//! `*_sampled`) AOT artifacts and the per-slot `KvCache` occupancy ledger
//! (and any `&mut E` borrows a scheduler for one phase — the rollout
//! subsystem's shape).
//!
//! # Variable prompt lengths (the two alignment contracts)
//!
//! The AOT artifacts are fixed-shape, but admitted prompts are NOT: any
//! request of true length `1..=prompt_len` is accepted. How a short
//! prompt rides the fixed shape depends on the engine's cache layout
//! ([`SlotEngine::paged`]):
//!
//! * **Arena engines LEFT-PAD**: `pad = prompt_len - len` dead entries at
//!   the front, and the per-row **valid start** (`= pad`) is threaded to
//!   the artifacts, which mask cache entries before it out of attention
//!   and shift position embeddings so real token `j` is embedded at
//!   logical position `j` — the padded computation is bit-identical to
//!   running the unpadded prompt at its exact length (pinned by the
//!   mixed-length goldens in `rust/tests/integration_serving.rs` and the
//!   pytest oracle suite). Left-alignment at the window's right edge means
//!   every slot's next cache write is at `prompt_len`; a slot's decode
//!   position is `pad + true_len`. Short prompts require the artifact
//!   set's `padded_prompts` capability
//!   ([`SlotEngine::supports_padded_prompts`]) — submission bails with
//!   the rebuild command against pre-capability artifacts.
//! * **Paged engines FRONT-ALIGN**: real token `j` sits at logical row
//!   `j`, the window's TAIL is the dead region (the causal mask keeps
//!   rows `0..len` blind to it), and `pad` is always 0 — so decode
//!   positions are just `len(tokens) - 1` and every valid start is 0.
//!   Front alignment is what makes a shared prompt PREFIX occupy the same
//!   logical rows in every slot that shares it, which is what lets block
//!   tables map one physical page into many slots (see below). The paged
//!   artifacts bit-match the arena ones for identical traffic (pinned by
//!   the paged goldens in `python/tests/test_paged.py` and
//!   `rust/tests/integration_serving.rs`).
//!
//! In both contracts all length accounting ([`SchedStats`], `KvCache`
//! occupancy, [`Completion`]) counts VALID tokens only; arena padding
//! overhead is tracked separately ([`SchedStats::pad_fraction`]) for the
//! serve bench.
//!
//! # Block-paged serving and shared-prefix reuse
//!
//! A paged engine keeps K/V in a pool of fixed-size pages behind
//! refcounted per-slot block tables (`crate::hybrid::kv::PageLedger`).
//! Admission goes through the [`Admission`] descriptor: a request may
//! declare [`Request::prefix_len`] — the length of a prompt prefix shared
//! with other requests (a common system prompt). The engine hashes the
//! page-aligned prefix; on a registry hit the prefix's pages are MAPPED
//! into the new slot's block table instead of being recomputed-from-cold,
//! and the admission's [`AdmitOutcome::reused_tokens`] reports how many
//! prompt tokens were served from cache. The scheduler folds those into
//! [`SchedStats::reused_tokens`] / [`SchedStats::prefix_hits`] /
//! [`SchedStats::cache_hit_rate`] — the serve bench's
//! computed-vs-admitted saving. Sharing never changes bytes: a hit
//! rewrites the shared pages with bit-identical values and decode writes
//! land past the prompt region in private pages, so completions are
//! bit-identical with sharing on or off (pinned by the prefix goldens).
//! Arena engines ignore `prefix_len` and always report zero reuse.
//!
//! The scheduler serves two consumers: the serve loop (one request per
//! client, completions returned per step) and RLHF experience generation
//! (`crate::rollout`, which oversubscribes the queue with a whole prompt
//! batch — mixed lengths welcome — and streams completions into an
//! `ExperienceBuffer` through the [`CompletionSink`] that
//! [`Scheduler::step_into`] takes). Requests may carry their own
//! RNG-stream seed ([`Request::seed`]) so stochastic sampling stays
//! reproducible even though retirement — and therefore the order sample
//! calls interleave across requests — is data-dependent.
//!
//! # Failure semantics ([`FaultPolicy`])
//!
//! Engine calls can fail transiently (a flaky device, an injected chaos
//! fault) or permanently (a bad slot, a wedged artifact). The scheduler
//! owns recovery so one fault never aborts the whole batch:
//!
//! * **Prefill fault → requeue with backoff.** A failed
//!   [`SlotEngine::prefill_slot`] releases whatever KV rows the admission
//!   may have claimed (best-effort; the hybrid engine claims rows only
//!   after its artifact call succeeds) and puts the request back in the
//!   queue, not admissible again for [`FaultPolicy::backoff_steps`]
//!   ticks. After [`FaultPolicy::max_retries`] faulted admissions the
//!   request retires with [`FinishReason::Failed`] — reported to the
//!   caller, never silently dropped.
//! * **Decode fault → bounded retry, then retire the tick's sequences.**
//!   A failed [`SlotEngine::decode_slots`] tick is retried with identical
//!   inputs up to [`FaultPolicy::max_retries`] times; if every attempt
//!   fails, all live sequences retire with [`FinishReason::Failed`] and
//!   the scheduler keeps serving the queue.
//! * **Repeatedly-failing slots quarantine.** A slot whose prefills fault
//!   [`FaultPolicy::quarantine_after`] consecutive times is removed from
//!   the free list (counted in [`SchedStats::quarantined`]) so one bad
//!   slot cannot eat every admission. Every slot quarantined with work
//!   still queued is a loud error.
//! * **Deadlines.** [`FaultPolicy::deadline_steps`] bounds a request's
//!   decode-step residency; at the deadline it retires with
//!   [`FinishReason::Deadline`] *before* sampling that tick, so a stuck
//!   request frees its slot instead of holding KV forever.
//!
//! Retries must not perturb generation: each tick samples from the pending
//! row of the last *successful* engine call, and per-request RNG streams
//! advance only when a token is actually sampled. A transient fault
//! injected before the engine touched per-slot state therefore recovers
//! **bit-identically** — under transient-only chaos, greedy completions
//! match the fault-free run exactly (pinned by the chaos goldens in
//! `rust/tests/failure_injection.rs`). [`chaos::ChaosEngine`] injects
//! deterministic faults and slow ticks for those tests and for the serve
//! bench's chaos phase.

pub mod chaos;

use std::collections::VecDeque;

use anyhow::{bail, Result};

use crate::data::synthetic::Vocab;
use crate::hybrid::HybridEngine;
use crate::sampling::{seed_words, PendingRow, RowRef, SampleOut, SamplingBackend, TrafficClass};
use crate::telemetry::{self, Hist, Telemetry};
use crate::util::rng::Rng;

/// Everything one admission needs, in one descriptor (the per-argument
/// `prefill_slot(slot, prompt, traffic)` signature stopped scaling when
/// shared-prefix admission arrived — adding fields here no longer breaks
/// every engine impl).
#[derive(Debug, Clone, Copy)]
pub struct Admission<'a> {
    /// The prompt's TRUE tokens (any length `1..=prompt_len`, no padding).
    pub prompt: &'a [i32],
    /// Length of the prompt prefix shared with other requests (see
    /// [`Request::prefix_len`]); 0 = nothing shared. Arena engines ignore
    /// it.
    pub prefix_len: usize,
    /// Which artifact family / pending-row shape the admission produces.
    pub traffic: TrafficClass,
    /// Device-RNG inputs of the admission draw (`Some` iff `traffic` is
    /// [`TrafficClass::DeviceCategorical`] — the `_rng` artifacts draw the
    /// request's FIRST token on device, always at step 0 of its stream).
    pub rng: Option<AdmissionRng>,
}

/// Device-RNG inputs of one admission (the `prefill_*_rng` artifacts).
#[derive(Debug, Clone, Copy)]
pub struct AdmissionRng {
    /// The request's Threefry key words `[hi, lo]`
    /// ([`crate::sampling::seed_words`] of its u64 seed).
    pub seed: [i32; 2],
    /// `[temperature, top_k, top_p]` — the backend's
    /// [`SamplingBackend::device_params`].
    pub sparams: [f32; 3],
}

/// Device-RNG inputs of one fused decode call (the `decode_*_rng` and
/// `decode_chunk{N}` artifacts): per-slot Threefry keys and draw-step
/// counters, plus the sampling params shared by the whole batch.
#[derive(Debug, Clone, Copy)]
pub struct DecodeRng<'a> {
    /// Per-slot Threefry key words, flat `[b, 2]` (zeros for dead rows).
    pub seeds: &'a [i32],
    /// Per-slot step counter of the NEXT draw = tokens the request has
    /// accepted so far (the device advances it per accepted token inside
    /// a chunk, so streams survive chunking unchanged).
    pub steps: &'a [i32],
    /// `[temperature, top_k, top_p]`.
    pub sparams: [f32; 3],
}

/// One fused decode step over every slot, as a typed batch (replaces the
/// four parallel slices the old `decode_slots` took positionally — the
/// call sites were unreadable and unextendable).
#[derive(Debug, Clone, Copy)]
pub struct DecodeBatch<'a> {
    /// Per slot: the newest sampled token (PAD for dead rows).
    pub toks: &'a [i32],
    /// Per slot: logical cache row the token writes at (`pad + len - 1`;
    /// 0 for dead rows).
    pub pos: &'a [i32],
    /// Per slot: valid start = left-pad width (always 0 on paged engines
    /// and dead rows).
    pub starts: &'a [i32],
    /// Per slot: whether the row carries a live sequence.
    pub active: &'a [bool],
    pub traffic: TrafficClass,
    /// Device-RNG inputs (`Some` iff `traffic` is
    /// [`TrafficClass::DeviceCategorical`]).
    pub rng: Option<DecodeRng<'a>>,
}

/// One fused `N`-token decode over every slot (the `decode_chunk{N}`
/// artifact family; device-RNG only, so [`ChunkBatch::rng`] is not
/// optional). Compared to [`DecodeBatch`] it adds the per-slot generation
/// budget the device's freeze latch honors.
#[derive(Debug, Clone, Copy)]
pub struct ChunkBatch<'a> {
    /// Per slot: the newest accepted token (PAD for dead rows) — the
    /// chunk's first K/V write, exactly like the stepwise fed token.
    pub toks: &'a [i32],
    /// Per slot: logical cache row `toks` writes at (`len - 1` on the
    /// paged layout; 0 for dead rows).
    pub pos: &'a [i32],
    /// Per slot: whether the row carries a live sequence (dead rows enter
    /// the chunk frozen: no draws, garbage-page writes only).
    pub active: &'a [bool],
    /// Fused steps per dispatch (the artifact's `N`; `>= 2`).
    pub n: usize,
    /// Per slot: remaining generation budget (`max_new - generated`); the
    /// device freezes a row that exhausts it mid-chunk.
    pub quota: &'a [i32],
    pub rng: DecodeRng<'a>,
}

/// How many of one slot's `n` chunk-emitted tokens are real: everything
/// up to and including the first EOS, capped by the slot's remaining
/// `quota`. The device's freeze latch stops at the same boundary, so the
/// scheduler's token walk and the engine's KV-ledger advance — both
/// computed with this function over the same `[n, b]` row-major ids —
/// agree by construction. Tokens past the boundary are frozen filler and
/// must never be read. A `quota` of 0 consumes NOTHING: the row had no
/// budget to emit even one token, so every id in it is filler (the
/// scheduler never dispatches a live row in that state — live slots
/// always hold `quota >= 1` — but a zero-quota row must not read frozen
/// filler as if it were real).
pub fn chunk_consumed(ids: &[i32], b: usize, slot: usize, n: usize, quota: usize) -> usize {
    if quota == 0 {
        return 0;
    }
    let mut consumed = 0;
    for j in 0..n {
        consumed += 1;
        if ids[j * b + slot] == Vocab::EOS || consumed >= quota {
            break;
        }
    }
    consumed
}

/// What an admission produced: the slot's first pending row plus the
/// engine's cache-reuse report.
#[derive(Debug, Clone)]
pub struct AdmitOutcome {
    /// Sampling view predicting the first generated token (logits, id, or
    /// top-k candidates per the traffic class).
    pub pending: PendingRow,
    /// Prompt tokens served from a shared-prefix cache hit instead of
    /// being computed from cold (0 on arena engines and registry misses).
    pub reused_tokens: usize,
    /// Whether a shared-prefix registry hit backed this admission.
    pub prefix_hit: bool,
}

impl AdmitOutcome {
    /// The no-reuse outcome every non-paged engine returns.
    pub fn cold(pending: PendingRow) -> AdmitOutcome {
        AdmitOutcome { pending, reused_tokens: 0, prefix_hit: false }
    }
}

/// What the scheduler needs from a generation engine with per-slot state.
/// (Row strides are carried by [`SampleOut`]/[`PendingRow`] themselves, so
/// the engine no longer exposes a vocab size here.)
pub trait SlotEngine {
    /// Number of batch slots (the artifact batch size).
    fn n_slots(&self) -> usize;
    /// The fixed prompt window of the AOT shapes — the CAP on admitted
    /// prompt lengths. Shorter prompts are left-padded (arena) or
    /// front-aligned (paged) up to it (see the module docs' alignment
    /// contracts).
    fn prompt_len(&self) -> usize;
    /// Hard cap on generated tokens per sequence (KV-cache capacity).
    fn max_new_tokens(&self) -> usize;
    /// Whether prompts SHORTER than [`SlotEngine::prompt_len`] can be
    /// admitted (the artifact set's `padded_prompts` capability — per-row
    /// valid-start masking). Engines without it only take exact-length
    /// prompts; [`Scheduler::submit`] refuses short ones up front. The
    /// default FAILS CLOSED: an engine that cannot mask left-padding but
    /// admitted a short prompt would attend its own padding — a silent
    /// wrong answer — so opting in must be explicit.
    fn supports_padded_prompts(&self) -> bool {
        false
    }
    /// Whether the engine serves from a block-paged cache (front-aligned
    /// prompts, `pad == 0`, shared-prefix reuse; see the module docs).
    /// Paged engines admit short prompts without the `padded_prompts`
    /// capability — the causal mask, not a valid-start, hides the dead
    /// tail.
    fn paged(&self) -> bool {
        false
    }
    /// Enter serving mode (install an empty per-slot cache).
    fn begin_serving(&mut self) -> Result<()> {
        Ok(())
    }
    /// Admit one prompt into a free slot; returns the slot's pending row
    /// plus the engine's cache-reuse report.
    fn prefill_slot(&mut self, slot: usize, adm: &Admission) -> Result<AdmitOutcome>;
    /// Advance every `active` slot by one token at its own position.
    /// Returns the batch's sampling view (only active rows meaningful).
    fn decode_slots(&mut self, batch: &DecodeBatch) -> Result<SampleOut>;
    /// Whether this engine can execute fused `n`-token decode chunks
    /// (`n == 1` is always fine — it is the stepwise path). The scheduler
    /// checks at [`Scheduler::set_decode_chunk`] time so a missing
    /// capability fails loudly up front, with the engine's own
    /// actionable error, instead of failing every tick. The default
    /// FAILS CLOSED for `n > 1`.
    fn check_decode_chunk(&self, n: usize) -> Result<()> {
        if n <= 1 {
            Ok(())
        } else {
            bail!("engine does not support fused decode chunks (no decode_chunk artifacts)")
        }
    }
    /// Advance every `active` slot by up to `batch.n` tokens in ONE fused
    /// call (the `decode_chunk{N}` artifact family). Returns the emitted
    /// ids, row-major `[n, b]`; a slot's tokens past its freeze boundary
    /// ([`chunk_consumed`]) are filler the caller must not read. Engines
    /// without the capability keep the default, which fails closed.
    fn decode_slots_chunk(&mut self, batch: &ChunkBatch) -> Result<Vec<i32>> {
        let _ = batch;
        bail!("engine does not support fused decode chunks (no decode_chunk artifacts)")
    }
    /// Whether the engine's KV pool can cover admitting `prompt` right now
    /// (free pages plus prefixes evictable under LRU; a declared shared
    /// prefix that hits the registry reduces the draw). Engines without an
    /// oversubscribable pool always admit. The scheduler DEFERS an
    /// admission this predicate refuses while live sequences still hold
    /// pages — retiring them frees capacity — and admits anyway on an
    /// otherwise-empty engine so an undersized pool fails loudly instead
    /// of deadlocking the queue.
    fn can_admit(&self, prompt: &[i32], prefix_len: usize) -> bool {
        let _ = (prompt, prefix_len);
        true
    }
    /// Reserve KV coverage for `n` more decode rows on `slot` BEFORE the
    /// decode dispatch writes them (lazy paged pools draw pages on demand;
    /// the write-before-advance contract needs the pages mapped up front).
    /// `Ok(false)` means the pool is exhausted and the slot must be
    /// PREEMPTED — requeued for recompute — rather than dispatched.
    /// Engines without lazy page growth always succeed.
    fn reserve_decode(&mut self, slot: usize, n: usize) -> Result<bool> {
        let _ = (slot, n);
        Ok(true)
    }
    /// Retire a finished sequence, freeing its slot for the next admission.
    fn release_slot(&mut self, slot: usize) -> Result<()>;
    /// Accounting hook: `n` tokens were sampled this step.
    fn note_generated(&mut self, _n: u64) {}
    /// The telemetry handle the engine records into — the scheduler
    /// adopts it at construction so request-lifecycle spans and the
    /// engine's own events land in one shared timeline. The default is
    /// the disabled (free) handle; [`Scheduler::set_telemetry`] can
    /// override per scheduler.
    fn telemetry(&self) -> Telemetry {
        Telemetry::disabled()
    }
}

/// A mutable borrow of a slot engine is itself a slot engine — this is what
/// lets the rollout subsystem build a [`Scheduler`] over `&mut HybridEngine`
/// for the duration of one experience-generation phase and hand the engine
/// back for scoring and training afterwards (the serve loop keeps owning
/// its engine through `Scheduler<HybridEngine>` as before).
impl<E: SlotEngine> SlotEngine for &mut E {
    fn n_slots(&self) -> usize {
        (**self).n_slots()
    }

    fn prompt_len(&self) -> usize {
        (**self).prompt_len()
    }

    fn max_new_tokens(&self) -> usize {
        (**self).max_new_tokens()
    }

    fn supports_padded_prompts(&self) -> bool {
        (**self).supports_padded_prompts()
    }

    fn paged(&self) -> bool {
        (**self).paged()
    }

    fn begin_serving(&mut self) -> Result<()> {
        (**self).begin_serving()
    }

    fn prefill_slot(&mut self, slot: usize, adm: &Admission) -> Result<AdmitOutcome> {
        (**self).prefill_slot(slot, adm)
    }

    fn decode_slots(&mut self, batch: &DecodeBatch) -> Result<SampleOut> {
        (**self).decode_slots(batch)
    }

    fn check_decode_chunk(&self, n: usize) -> Result<()> {
        (**self).check_decode_chunk(n)
    }

    fn decode_slots_chunk(&mut self, batch: &ChunkBatch) -> Result<Vec<i32>> {
        (**self).decode_slots_chunk(batch)
    }

    fn can_admit(&self, prompt: &[i32], prefix_len: usize) -> bool {
        (**self).can_admit(prompt, prefix_len)
    }

    fn reserve_decode(&mut self, slot: usize, n: usize) -> Result<bool> {
        (**self).reserve_decode(slot, n)
    }

    fn release_slot(&mut self, slot: usize) -> Result<()> {
        (**self).release_slot(slot)
    }

    fn note_generated(&mut self, n: u64) {
        (**self).note_generated(n)
    }

    fn telemetry(&self) -> Telemetry {
        (**self).telemetry()
    }
}

impl SlotEngine for HybridEngine {
    fn n_slots(&self) -> usize {
        self.manifest().batch
    }

    fn prompt_len(&self) -> usize {
        self.manifest().prompt_len
    }

    fn max_new_tokens(&self) -> usize {
        self.manifest().gen_len
    }

    fn supports_padded_prompts(&self) -> bool {
        self.manifest().padded_prompts
    }

    fn paged(&self) -> bool {
        HybridEngine::serving_is_paged(self)
    }

    fn begin_serving(&mut self) -> Result<()> {
        HybridEngine::begin_serving(self)
    }

    fn prefill_slot(&mut self, slot: usize, adm: &Admission) -> Result<AdmitOutcome> {
        HybridEngine::prefill_slot(self, slot, adm)
    }

    fn decode_slots(&mut self, batch: &DecodeBatch) -> Result<SampleOut> {
        HybridEngine::decode_slots(self, batch)
    }

    fn check_decode_chunk(&self, n: usize) -> Result<()> {
        if n <= 1 {
            return Ok(());
        }
        if !self.serving_is_paged() {
            bail!(
                "fused decode chunks serve from the block-paged KV pool only — \
                 enable use_paged_serving(true) before set_decode_chunk({n})"
            );
        }
        self.manifest().require_device_rng()?;
        self.manifest().require_decode_chunk(n)
    }

    fn decode_slots_chunk(&mut self, batch: &ChunkBatch) -> Result<Vec<i32>> {
        HybridEngine::decode_slots_chunk(self, batch)
    }

    fn can_admit(&self, prompt: &[i32], prefix_len: usize) -> bool {
        HybridEngine::kv_can_admit(self, prompt, prefix_len)
    }

    fn reserve_decode(&mut self, slot: usize, n: usize) -> Result<bool> {
        HybridEngine::kv_reserve_rows(self, slot, n)
    }

    fn release_slot(&mut self, slot: usize) -> Result<()> {
        HybridEngine::release_slot(self, slot)
    }

    fn note_generated(&mut self, n: u64) {
        self.stats.gen_tokens += n;
    }

    fn telemetry(&self) -> Telemetry {
        self.telemetry.clone()
    }
}

/// One queued generation request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    /// Any length `1..=prompt_len`: the AOT artifacts are fixed-shape, but
    /// shorter prompts are LEFT-PADDED into the fixed window at admission
    /// and masked via the artifacts' per-row valid-start inputs (see the
    /// module docs). Admitting a short prompt requires the engine's
    /// `padded_prompts` capability.
    pub prompt: Vec<i32>,
    /// Requested generation budget; capped at the engine's
    /// [`SlotEngine::max_new_tokens`].
    pub max_new: usize,
    /// How many leading prompt tokens are a prefix SHARED with other
    /// requests (a common system prompt); 0 = nothing shared. On a paged
    /// engine the page-aligned part of this prefix is admitted through
    /// the shared-prefix registry (copy-on-write page mapping — see the
    /// module docs); arena engines ignore it. Must be `<= prompt.len()`.
    /// Declaring a prefix never changes the completion's bytes, only how
    /// much prompt computation a cache hit saves.
    pub prefix_len: usize,
    /// Seed of this request's own RNG stream. `Some(s)` makes the
    /// scheduler finish every one of the request's tokens through
    /// [`SamplingBackend::sample_stream`] over `Rng::new(s)`, so the
    /// sampled sequence is a pure function of `(prompt, s)` no matter what
    /// else shares the batch — the rollout reproducibility contract.
    /// `None` (the serve loop) uses the backend's global stream. Under a
    /// [`TrafficClass::DeviceCategorical`] backend the seed keys the
    /// request's DEVICE Threefry stream instead (same purity contract,
    /// stronger: the counter-based draw is also independent of slot
    /// placement and chunking); `None` falls back to a deterministic
    /// per-id key.
    pub seed: Option<u64>,
}

/// How the scheduler survives engine faults (see the module docs'
/// "Failure semantics" section). The default policy retries transients,
/// backs off requeued admissions by one tick, quarantines a slot after
/// three consecutive prefill faults, and imposes no deadline.
#[derive(Debug, Clone)]
pub struct FaultPolicy {
    /// Engine-call retries before giving up: a request whose admission
    /// faults more than this many times retires as
    /// [`FinishReason::Failed`]; a decode tick is re-attempted this many
    /// times before the tick's sequences retire.
    pub max_retries: u32,
    /// Scheduler ticks a request requeued after a prefill fault must wait
    /// before it is admissible again (floored at 1).
    pub backoff_steps: u64,
    /// Per-request residency cap in decode steps from admission; a
    /// sequence still live after this many ticks retires with
    /// [`FinishReason::Deadline`] before sampling. `0` disables.
    pub deadline_steps: u64,
    /// Consecutive prefill faults on one slot before it is quarantined
    /// (removed from the free list). `0` disables quarantine.
    pub quarantine_after: u32,
}

impl Default for FaultPolicy {
    fn default() -> Self {
        FaultPolicy {
            max_retries: 2,
            backoff_steps: 1,
            deadline_steps: 0,
            quarantine_after: 3,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    /// The model emitted EOS (included as the sequence's last token).
    Eos,
    /// The per-request or engine generation budget was exhausted.
    Length,
    /// Engine faults exhausted [`FaultPolicy::max_retries`]; `retries` is
    /// how many faulted attempts this request absorbed before retiring.
    /// The sequence's tokens are whatever was generated before the fault
    /// (prompt only, if admission never succeeded).
    Failed { retries: u32 },
    /// The request hit [`FaultPolicy::deadline_steps`] and was retired to
    /// free its slot; tokens generated before the deadline are kept.
    Deadline,
    /// Mid-decode KV-pool exhaustion preempted the sequence more than
    /// [`FaultPolicy::max_retries`] times; `preemptions` is how many times
    /// it lost its pages. Tokens generated before the final preemption are
    /// kept (each earlier preemption requeued the request for a
    /// from-scratch recompute instead).
    Preempted { preemptions: u32 },
}

/// A finished sequence handed back by [`Scheduler::step`].
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: u64,
    /// Batch slot the sequence occupied (diagnostic).
    pub slot: usize,
    pub prompt_len: usize,
    /// Prompt ++ generated tokens (EOS included when emitted; no padding).
    pub tokens: Vec<i32>,
    pub generated: usize,
    pub finish: FinishReason,
    /// Scheduler steps spent waiting in the queue before admission.
    pub queued_steps: u64,
    /// Scheduler steps from admission to retirement.
    pub decode_steps: u64,
}

impl Completion {
    /// The generated suffix (response) of the sequence.
    pub fn response(&self) -> &[i32] {
        &self.tokens[self.prompt_len..]
    }
}

/// A queue entry: the request plus its admission/backoff bookkeeping.
struct Queued {
    req: Request,
    /// Step the request was first submitted (queue-delay accounting).
    enqueued_step: u64,
    /// Earliest step this entry may be admitted again (backoff after a
    /// prefill fault; 0 = immediately).
    not_before: u64,
    /// Admission attempts that ended in a prefill fault.
    attempts: u32,
    /// Telemetry submit timestamp (us; 0 when telemetry is disabled) —
    /// the queue-wait and TTFT histograms both anchor here.
    t_submit_us: u64,
}

/// A sequence occupying one batch slot.
struct Seq {
    id: u64,
    /// TRUE tokens only (prompt ++ generated) — padding never lands here.
    tokens: Vec<i32>,
    /// TRUE prompt length (<= the engine's fixed prompt window).
    prompt_len: usize,
    /// Left-pad width the prompt was admitted with (`prompt window -
    /// prompt_len`); the slot's cache position for token index `j` is
    /// `pad + j`, and `pad` is fed to the fused decode as the slot's
    /// valid start.
    pad: usize,
    generated: usize,
    max_new: usize,
    /// The request's declared shared-prefix length (kept so a PREEMPTED
    /// sequence can be requeued as the request it came from).
    prefix_len: usize,
    /// The request's explicit seed (requeue bookkeeping, like
    /// `prefix_len`); the live stream state is `rng`/`device_seed`.
    seed: Option<u64>,
    /// Faulted admissions + preemptions this request has absorbed (the
    /// shared [`FaultPolicy::max_retries`] budget).
    attempts: u32,
    /// Pending sampling view predicting the next token (from the
    /// admission prefill or the last fused decode).
    pending: PendingRow,
    /// Per-request RNG stream (see [`Request::seed`]); `None` falls back
    /// to the backend's global stream. Always `None` under a
    /// device-categorical backend — the host draws nothing there.
    rng: Option<Rng>,
    /// Key of the request's device Threefry stream (device-categorical
    /// backends only; 0 otherwise). Draw `j` of the request is
    /// `threefry(seed_words(device_seed), j)` wherever it executes.
    device_seed: u64,
    enqueued_step: u64,
    admitted_step: u64,
    /// Telemetry timestamps (us; 0 when telemetry is disabled): the
    /// request's submit time and the arrival time of its latest token
    /// (TTFT / inter-token histogram anchors).
    t_submit_us: u64,
    t_last_tok_us: u64,
}

/// Counters for the serve log, the `serve_loop` bench, and the rollout
/// bench's slot-occupancy accounting.
#[derive(Debug, Default, Clone)]
pub struct SchedStats {
    pub submitted: u64,
    pub admitted: u64,
    pub completed: u64,
    /// Scheduler ticks ([`Scheduler::step`] calls).
    pub steps: u64,
    /// Fused decode calls issued (<= steps; idle ticks issue none).
    pub decode_calls: u64,
    pub prefills: u64,
    pub peak_queue_depth: usize,
    /// Busy slot-steps across all decode calls (utilization numerator).
    pub slot_steps_active: u64,
    /// Total slot-steps across all decode calls (`decode_calls * n_slots`).
    pub slot_steps_total: u64,
    /// Tokens sampled across all steps (every live slot, every tick).
    /// VALID tokens only — padding is never sampled and never counted.
    pub tokens_sampled: u64,
    /// Sequences retired on EOS (the early exits continuous batching
    /// converts into fresh admissions instead of dead decode rows).
    pub retired_eos: u64,
    /// Sequences retired on the per-request/engine budget.
    pub retired_length: u64,
    /// VALID prompt tokens across all admissions (true lengths).
    pub prompt_tokens: u64,
    /// Left-padding entries written by admissions (the fixed prompt
    /// window minus the true length, summed) — the padded-token overhead
    /// the serve bench reports for mixed-length traffic.
    pub pad_tokens: u64,
    /// Failed `prefill_slot` calls observed (each requeues or retires its
    /// request per the [`FaultPolicy`]).
    pub prefill_faults: u64,
    /// Failed fused-decode calls observed (including failed retries).
    pub decode_faults: u64,
    /// Decode re-attempts issued after a fault (a transient fault
    /// recovered on the first retry contributes 1 here and 1 to
    /// `decode_faults`).
    pub decode_retries: u64,
    /// Requests put back in the queue with backoff after a prefill fault.
    pub requeues: u64,
    /// Sequences retired with [`FinishReason::Failed`] after faults
    /// exhausted the retry budget.
    pub retired_failed: u64,
    /// Sequences retired at the per-request deadline.
    pub retired_deadline: u64,
    /// Mid-decode preemptions: a live slot could not draw its next KV
    /// page and was requeued for recompute (or retired past the retry
    /// budget). Counts every preemption, not every preempted request.
    pub preemptions: u64,
    /// Sequences retired as [`FinishReason::Preempted`] after preemptions
    /// exhausted the shared retry budget.
    pub retired_preempted: u64,
    /// Admissions deferred at the step boundary because the KV pool could
    /// not cover the prompt (the request stayed queued; retried once live
    /// sequences release pages).
    pub admission_deferrals: u64,
    /// Slots removed from the free list after repeated prefill faults.
    pub quarantined: u64,
    /// Prompt tokens served from shared-prefix cache hits instead of
    /// being computed cold (paged engines only; see
    /// [`AdmitOutcome::reused_tokens`]).
    pub reused_tokens: u64,
    /// Paged admissions backed by a shared-prefix registry hit.
    pub prefix_hits: u64,
    /// Paged admissions that found no reusable prefix (cold prompts and
    /// sub-page prefixes land here; arena admissions are counted in
    /// neither bucket).
    pub prefix_misses: u64,
    /// Chunk slots burned by rows that froze mid-chunk (EOS or budget
    /// latch): for every live-at-dispatch slot of a fused `N`-token
    /// decode, the `N - consumed` trailing slots the device spent
    /// re-writing the frozen row. The chunk-granularity component of
    /// [`SchedStats::bubble_fraction`]; always 0 under stepwise (`N = 1`)
    /// serving.
    pub chunk_waste_tokens: u64,
}

impl SchedStats {
    /// Fraction of decode-call slot capacity that carried live sequences.
    pub fn utilization(&self) -> f64 {
        self.slot_steps_active as f64 / self.slot_steps_total.max(1) as f64
    }

    /// Fraction of decode-call slot capacity burned on dead rows — the
    /// slot-bubble metric the rollout bench tracks against the fixed-batch
    /// baseline (0 until the first decode call). Chunk-aware: under fused
    /// `N`-token decode the total counts every chunk slot
    /// (`decode_calls · n_slots · N`) while active counts only CONSUMED
    /// tokens, so both dead rows and mid-chunk freezes
    /// ([`SchedStats::chunk_waste_tokens`]) register as bubble.
    pub fn bubble_fraction(&self) -> f64 {
        if self.slot_steps_total == 0 {
            0.0
        } else {
            1.0 - self.utilization()
        }
    }

    /// Fraction of prefill-written prompt-window entries that were
    /// left-padding (0 for exact-length traffic; the padded-token overhead
    /// mixed-length serving pays for riding the fixed AOT shape).
    pub fn pad_fraction(&self) -> f64 {
        let total = self.prompt_tokens + self.pad_tokens;
        if total == 0 {
            0.0
        } else {
            self.pad_tokens as f64 / total as f64
        }
    }

    /// VALID prompt tokens admitted (alias of [`SchedStats::prompt_tokens`]
    /// under the serve bench's admitted-vs-computed vocabulary).
    pub fn admitted_tokens(&self) -> u64 {
        self.prompt_tokens
    }

    /// Prompt tokens actually computed cold — admitted minus the tokens
    /// shared-prefix hits served from cache. Equal to admitted on arena
    /// engines and prefix-free traffic; strictly smaller under
    /// prefix-heavy paged serving.
    pub fn computed_tokens(&self) -> u64 {
        self.prompt_tokens - self.reused_tokens
    }

    /// Fraction of paged admissions served by a shared-prefix hit (0 when
    /// no paged admission happened).
    pub fn cache_hit_rate(&self) -> f64 {
        let total = self.prefix_hits + self.prefix_misses;
        if total == 0 {
            0.0
        } else {
            self.prefix_hits as f64 / total as f64
        }
    }
}

/// Where retired sequences land. [`Scheduler::step_into`] pushes each
/// completion into the caller's sink the moment its slot frees — a `Vec`
/// for the serve loop, the rollout `ExperienceBuffer` for experience
/// generation, or anything else that wants completions streamed instead of
/// collected per step.
pub trait CompletionSink {
    fn complete(&mut self, c: Completion);
}

impl CompletionSink for Vec<Completion> {
    fn complete(&mut self, c: Completion) {
        self.push(c);
    }
}

/// The continuous-batching scheduler. Owns the engine; requests flow in
/// via [`Scheduler::submit`] and completed sequences flow out of
/// [`Scheduler::step`].
pub struct Scheduler<E: SlotEngine> {
    pub engine: E,
    pub stats: SchedStats,
    /// Recovery knobs for engine faults (see module docs).
    pub policy: FaultPolicy,
    queue: VecDeque<Queued>,
    slots: Vec<Option<Seq>>,
    /// Slots removed from the free list after repeated prefill faults; a
    /// quarantined slot is always empty (quarantine happens at a failed
    /// admission, when the slot holds no sequence).
    quarantined: Vec<bool>,
    /// Consecutive prefill faults per slot (reset on success).
    slot_failures: Vec<u32>,
    step_idx: u64,
    /// Fused decode steps per tick (see [`Scheduler::set_decode_chunk`]);
    /// 1 = stepwise legacy path.
    chunk: usize,
    /// Reused per-step decode inputs (the hot loop must not allocate).
    step_toks: Vec<i32>,
    step_pos: Vec<i32>,
    step_starts: Vec<i32>,
    step_active: Vec<bool>,
    /// Device-RNG per-step inputs: flat `[b, 2]` Threefry key words,
    /// `[b]` draw-step counters, `[b]` remaining budgets (chunk latch).
    step_seeds: Vec<i32>,
    step_steps: Vec<i32>,
    step_quota: Vec<i32>,
    /// Request-lifecycle event recorder (adopted from the engine at
    /// construction; disabled = free). See [`crate::telemetry`].
    tel: Telemetry,
}

impl<E: SlotEngine> Scheduler<E> {
    /// Wrap an engine and enter serving mode (empty cache, all slots free)
    /// under the default [`FaultPolicy`].
    pub fn new(engine: E) -> Result<Self> {
        Scheduler::with_policy(engine, FaultPolicy::default())
    }

    /// [`Scheduler::new`] with an explicit fault policy.
    pub fn with_policy(mut engine: E, policy: FaultPolicy) -> Result<Self> {
        engine.begin_serving()?;
        let n = engine.n_slots();
        let tel = engine.telemetry();
        Ok(Scheduler {
            engine,
            stats: SchedStats::default(),
            policy,
            queue: VecDeque::new(),
            slots: (0..n).map(|_| None).collect(),
            quarantined: vec![false; n],
            slot_failures: vec![0; n],
            step_idx: 0,
            chunk: 1,
            step_toks: vec![Vocab::PAD; n],
            step_pos: vec![0; n],
            step_starts: vec![0; n],
            step_active: vec![false; n],
            step_seeds: vec![0; 2 * n],
            step_steps: vec![0; n],
            step_quota: vec![0; n],
            tel,
        })
    }

    /// Replace the telemetry recorder (the benches attach a fresh enabled
    /// handle per phase; tests attach one to a mock engine's scheduler).
    pub fn set_telemetry(&mut self, tel: Telemetry) {
        self.tel = tel;
    }

    /// The scheduler's telemetry recorder (shared handle).
    pub fn telemetry(&self) -> &Telemetry {
        &self.tel
    }

    /// Fuse `n` decode steps into one engine dispatch per tick (see the
    /// module docs' chunk section). Fails loudly — with the engine's own
    /// actionable error — when the engine lacks the `decode_chunk{n}`
    /// capability; `n = 1` restores the stepwise legacy path and is always
    /// accepted. Chunked ticks additionally require a
    /// [`TrafficClass::DeviceCategorical`] backend, checked per step.
    pub fn set_decode_chunk(&mut self, n: usize) -> Result<()> {
        if n == 0 {
            bail!("decode chunk must be >= 1");
        }
        self.engine.check_decode_chunk(n)?;
        self.chunk = n;
        Ok(())
    }

    /// Fused decode steps per tick (1 = stepwise).
    pub fn decode_chunk(&self) -> usize {
        self.chunk
    }

    /// Tear the scheduler down and hand the engine back (the serve bench's
    /// re-wrap path: run fault-free, then wrap the same engine in chaos).
    pub fn into_engine(self) -> E {
        self.engine
    }

    /// Abandon all queued and in-flight sequences and re-enter serving
    /// mode with a fresh cache — the recovery path after a failed step
    /// left slot state suspect. The caller is responsible for replying to
    /// the abandoned requests. Quarantined slots stay quarantined: a fresh
    /// cache does not absolve a slot that faulted repeatedly.
    pub fn reset(&mut self) -> Result<()> {
        self.queue.clear();
        for s in self.slots.iter_mut() {
            *s = None;
        }
        for f in self.slot_failures.iter_mut() {
            *f = 0;
        }
        self.engine.begin_serving()
    }

    /// Enqueue a request; it is admitted at the next step boundary with a
    /// free slot. Prompts may be any length `1..=prompt_len` — shorter
    /// ones are left-padded at admission (capability-gated; see module
    /// docs). The queue is unbounded — backpressure is visible through
    /// [`Scheduler::queue_depth`].
    pub fn submit(&mut self, req: Request) -> Result<()> {
        let cap = self.engine.prompt_len();
        let len = req.prompt.len();
        if len == 0 || len > cap {
            bail!(
                "request {} prompt must be 1..={cap} tokens, got {len}",
                req.id,
            );
        }
        if len < cap && !self.engine.supports_padded_prompts() && !self.engine.paged() {
            bail!(
                "request {}: prompt is {len} tokens but the engine's artifacts only admit \
                 exact-length [{cap}] prompts (no `padded_prompts` capability / valid-start \
                 masks) — re-run `make artifacts` to rebuild with variable-length support",
                req.id,
            );
        }
        if req.prefix_len > len {
            bail!(
                "request {}: declared shared prefix ({} tokens) exceeds the prompt ({len})",
                req.id,
                req.prefix_len,
            );
        }
        self.stats.submitted += 1;
        let t_submit_us = if self.tel.is_enabled() {
            self.tel.begin(telemetry::TID_QUEUE, "queued", req.id, len as i64);
            self.tel.now_us()
        } else {
            0
        };
        self.queue.push_back(Queued {
            req,
            enqueued_step: self.step_idx,
            not_before: 0,
            attempts: 0,
            t_submit_us,
        });
        self.stats.peak_queue_depth = self.stats.peak_queue_depth.max(self.queue.len());
        Ok(())
    }

    /// Requests waiting for a slot.
    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    /// Slots currently quarantined (removed from the free list).
    pub fn n_quarantined(&self) -> usize {
        self.quarantined.iter().filter(|q| **q).count()
    }

    /// Sequences currently occupying slots.
    pub fn n_active(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// True when nothing is queued and no slot is busy.
    pub fn is_idle(&self) -> bool {
        self.queue.is_empty() && self.slots.iter().all(|s| s.is_none())
    }

    /// One scheduler iteration returning this step's completions as a
    /// `Vec` — a convenience wrapper over [`Scheduler::step_into`].
    pub fn step(&mut self, backend: &mut dyn SamplingBackend) -> Result<Vec<Completion>> {
        let mut out = Vec::new();
        self.step_into(backend, &mut out)?;
        Ok(out)
    }

    /// One scheduler iteration: admit → sample/retire → fused decode. The
    /// backend decides the artifact family (host full-row vs device
    /// sampled) and finishes each pending row into a token id; sequences
    /// that finish this step stream into `sink` in slot order. Returns how
    /// many retired.
    pub fn step_into(
        &mut self,
        backend: &mut dyn SamplingBackend,
        sink: &mut dyn CompletionSink,
    ) -> Result<usize> {
        let b = self.slots.len();
        let traffic = backend.traffic();
        let device = traffic == TrafficClass::DeviceCategorical;
        let dev_params = match (device, backend.device_params()) {
            (true, Some(p)) => Some(p),
            (true, None) => bail!(
                "sampling backend claims DeviceCategorical traffic but provides no \
                 device params (temperature/top_k/top_p)"
            ),
            (false, _) => None,
        };
        if self.chunk > 1 && !device {
            bail!(
                "decode chunk {} needs a device-RNG sampling backend (DeviceCategorical) — \
                 a host backend must see every token before the next step and cannot \
                 interleave its draws into a fused chunk",
                self.chunk
            );
        }
        self.stats.steps += 1;
        let mut retired = 0usize;

        // 1. Admission at the step boundary: every free, non-quarantined
        // slot takes the oldest admissible queued request; its prefill runs
        // while the other slots' device state stays live. An arena engine
        // left-pads short prompts into the fixed window (the scheduler
        // records the pad so the slot's decode positions — cache row = pad
        // + token index — and valid-start stay honest); a paged engine
        // front-aligns them (pad 0) and may serve a declared shared prefix
        // from its page registry, reported per-admission in the
        // AdmitOutcome and folded into the reuse stats. A faulted prefill
        // requeues its request with backoff (or retires it as Failed past
        // the retry budget) and leaves the slot empty this tick — see the
        // module docs' failure semantics.
        let cap = self.engine.prompt_len();
        let paged = self.engine.paged();
        if !self.queue.is_empty() && self.quarantined.iter().all(|q| *q) {
            bail!(
                "scheduler: all {b} slots quarantined after repeated prefill faults \
                 ({} observed) with {} request(s) still queued — engine is unserviceable",
                self.stats.prefill_faults,
                self.queue.len()
            );
        }
        for slot in 0..b {
            if self.slots[slot].is_some() || self.quarantined[slot] {
                continue;
            }
            // Oldest queued entry past its backoff window, if any.
            let Some(qidx) = self.queue.iter().position(|q| q.not_before <= self.step_idx)
            else {
                break;
            };
            // KV-capacity gate (lazy paged pools only): a prompt the pool
            // cannot cover would fault the prefill and burn a retry, so
            // defer it — leave the entry queued, in order, and stop the
            // admission pass (younger requests must not jump a deferred
            // head-of-line). Only defer while live sequences hold pages to
            // free; on an otherwise-empty engine admit anyway, so an
            // undersized pool fails loudly instead of deadlocking.
            {
                let cand = &self.queue[qidx];
                if !self.engine.can_admit(&cand.req.prompt, cand.req.prefix_len)
                    && self.slots.iter().any(|s| s.is_some())
                {
                    self.stats.admission_deferrals += 1;
                    break;
                }
            }
            let Some(q) = self.queue.remove(qidx) else {
                break;
            };
            // A device-categorical request without an explicit seed still
            // needs a key for its device stream: derive one from the id so
            // the stream stays a pure per-request function.
            let dseed = device.then(|| {
                q.req.seed.unwrap_or_else(|| crate::rollout::request_seed(0, q.req.id))
            });
            let adm = Admission {
                prompt: &q.req.prompt,
                prefix_len: q.req.prefix_len,
                traffic,
                rng: dseed.map(|s| AdmissionRng {
                    seed: seed_words(s),
                    sparams: dev_params.unwrap_or_default(),
                }),
            };
            // The queued span closes at the admission attempt either way:
            // a successful prefill hands the request to a slot track, a
            // faulted one re-opens the span on requeue (or ends the
            // request as aborted past the retry budget).
            let t_admit_us = self.tel.now_us();
            if self.tel.is_enabled() {
                self.tel
                    .end(telemetry::TID_QUEUE, "queued", q.req.id, q.attempts as i64);
                self.tel
                    .record(Hist::QueueWait, t_admit_us.saturating_sub(q.t_submit_us));
                self.tel.begin(
                    telemetry::slot_tid(slot),
                    "request",
                    q.req.id,
                    q.req.prompt.len() as i64,
                );
                self.tel
                    .begin(telemetry::slot_tid(slot), "prefill", q.req.id, 0);
            }
            match self.engine.prefill_slot(slot, &adm) {
                Ok(outcome) => {
                    self.tel.end(
                        telemetry::slot_tid(slot),
                        "prefill",
                        q.req.id,
                        outcome.reused_tokens as i64,
                    );
                    self.slot_failures[slot] = 0;
                    self.stats.prefills += 1;
                    self.stats.admitted += 1;
                    let true_len = q.req.prompt.len();
                    // Paged prompts are front-aligned: no left-padding, so
                    // the slot's cache row for token j is just j.
                    let pad = if paged { 0 } else { cap - true_len };
                    self.stats.prompt_tokens += true_len as u64;
                    self.stats.pad_tokens += pad as u64;
                    self.stats.reused_tokens += outcome.reused_tokens as u64;
                    if paged {
                        if outcome.prefix_hit {
                            self.stats.prefix_hits += 1;
                        } else {
                            self.stats.prefix_misses += 1;
                        }
                    }
                    let max_new = q.req.max_new.clamp(1, self.engine.max_new_tokens());
                    self.slots[slot] = Some(Seq {
                        id: q.req.id,
                        prompt_len: true_len,
                        pad,
                        tokens: q.req.prompt,
                        generated: 0,
                        max_new,
                        prefix_len: q.req.prefix_len,
                        seed: q.req.seed,
                        attempts: q.attempts,
                        pending: outcome.pending,
                        // Device-categorical draws run on device keyed by
                        // `device_seed`; the host stream stays unused.
                        rng: if device { None } else { q.req.seed.map(Rng::new) },
                        device_seed: dseed.unwrap_or(0),
                        enqueued_step: q.enqueued_step,
                        admitted_step: self.step_idx,
                        t_submit_us: q.t_submit_us,
                        t_last_tok_us: t_admit_us,
                    });
                }
                Err(_) => {
                    if self.tel.is_enabled() {
                        self.tel
                            .end(telemetry::slot_tid(slot), "prefill", q.req.id, -1);
                        self.tel.instant(
                            telemetry::slot_tid(slot),
                            "prefill_fault",
                            q.req.id,
                            (q.attempts + 1) as i64,
                        );
                    }
                    // The engine may have claimed KV rows before failing —
                    // release is best-effort (nothing claimed is fine; the
                    // hybrid engine claims only after its artifact call
                    // succeeds).
                    self.stats.prefill_faults += 1;
                    let _ = self.engine.release_slot(slot);
                    self.slot_failures[slot] += 1;
                    if self.policy.quarantine_after > 0
                        && self.slot_failures[slot] >= self.policy.quarantine_after
                    {
                        self.quarantined[slot] = true;
                        self.stats.quarantined += 1;
                        self.tel.instant(
                            telemetry::slot_tid(slot),
                            "quarantine",
                            q.req.id,
                            self.slot_failures[slot] as i64,
                        );
                    }
                    let attempts = q.attempts + 1;
                    if attempts > self.policy.max_retries {
                        // Retry budget exhausted: report the failure as a
                        // completion instead of dropping the request.
                        self.stats.completed += 1;
                        self.stats.retired_failed += 1;
                        retired += 1;
                        self.tel.end(
                            telemetry::slot_tid(slot),
                            "request",
                            q.req.id,
                            telemetry::FINISH_FAILED,
                        );
                        sink.complete(Completion {
                            id: q.req.id,
                            slot,
                            prompt_len: q.req.prompt.len(),
                            generated: 0,
                            finish: FinishReason::Failed { retries: attempts },
                            queued_steps: self.step_idx - q.enqueued_step,
                            decode_steps: 0,
                            tokens: q.req.prompt,
                        });
                    } else {
                        self.stats.requeues += 1;
                        if self.tel.is_enabled() {
                            // The aborted request span closes; the queued
                            // span re-opens so the next admission attempt
                            // pairs its own B/E (queue-wait still anchors
                            // at the original submit time).
                            self.tel.end(
                                telemetry::slot_tid(slot),
                                "request",
                                q.req.id,
                                telemetry::FINISH_ABORTED,
                            );
                            self.tel.instant(
                                telemetry::TID_QUEUE,
                                "requeue",
                                q.req.id,
                                attempts as i64,
                            );
                            self.tel.begin(
                                telemetry::TID_QUEUE,
                                "queued",
                                q.req.id,
                                attempts as i64,
                            );
                        }
                        self.queue.push_back(Queued {
                            not_before: self.step_idx + self.policy.backoff_steps.max(1),
                            attempts,
                            ..q
                        });
                    }
                    // Leave this slot empty this tick: a possibly-bad slot
                    // must not chew through the queue in one admission pass.
                }
            }
        }

        // 2. Sample one token per live slot; retire finished sequences
        // immediately so their slots are admissible next step. A sequence
        // past its deadline retires BEFORE sampling — no token, no RNG
        // draw — so deadline retirement never perturbs other streams.
        let mut sampled = 0u64;
        for slot in 0..b {
            let expired = self.policy.deadline_steps > 0
                && self.slots[slot]
                    .as_ref()
                    .is_some_and(|s| self.step_idx - s.admitted_step >= self.policy.deadline_steps);
            if expired {
                let Some(seq) = self.slots[slot].take() else {
                    bail!(
                        "scheduler invariant violated: slot {slot} vanished at deadline \
                         retirement (step {})",
                        self.step_idx
                    );
                };
                self.engine.release_slot(slot)?;
                self.stats.completed += 1;
                self.stats.retired_deadline += 1;
                retired += 1;
                self.tel.end(
                    telemetry::slot_tid(slot),
                    "request",
                    seq.id,
                    telemetry::FINISH_DEADLINE,
                );
                sink.complete(Completion {
                    id: seq.id,
                    slot,
                    prompt_len: seq.prompt_len,
                    generated: seq.generated,
                    finish: FinishReason::Deadline,
                    queued_steps: seq.admitted_step - seq.enqueued_step,
                    decode_steps: self.step_idx - seq.admitted_step,
                    tokens: seq.tokens,
                });
                continue;
            }
            let Some(seq) = self.slots[slot].as_mut() else {
                continue;
            };
            let t = match seq.rng.as_mut() {
                // Per-request stream: this sequence's draws are its own.
                Some(rng) => backend.sample_stream(seq.pending.as_row(), &seq.tokens, rng)?,
                None => backend.sample(seq.pending.as_row(), &seq.tokens)?,
            };
            seq.tokens.push(t);
            seq.generated += 1;
            sampled += 1;
            if self.tel.is_enabled() {
                let now = self.tel.now_us();
                if seq.generated == 1 {
                    self.tel
                        .instant(telemetry::slot_tid(slot), "first_token", seq.id, t as i64);
                    self.tel
                        .record(Hist::Ttft, now.saturating_sub(seq.t_submit_us));
                } else {
                    self.tel
                        .record(Hist::InterToken, now.saturating_sub(seq.t_last_tok_us));
                }
                seq.t_last_tok_us = now;
            }
            let finish = if t == Vocab::EOS {
                Some(FinishReason::Eos)
            } else if seq.generated >= seq.max_new {
                Some(FinishReason::Length)
            } else {
                None
            };
            if let Some(finish) = finish {
                let Some(seq) = self.slots[slot].take() else {
                    bail!(
                        "scheduler invariant violated: slot {slot} empty at retirement \
                         (step {})",
                        self.step_idx
                    );
                };
                self.engine.release_slot(slot)?;
                self.stats.completed += 1;
                match finish {
                    FinishReason::Eos => self.stats.retired_eos += 1,
                    FinishReason::Length => self.stats.retired_length += 1,
                    // Failed/Deadline/Preempted retirements never come
                    // through the sampling path.
                    FinishReason::Failed { .. }
                    | FinishReason::Deadline
                    | FinishReason::Preempted { .. } => {}
                }
                retired += 1;
                self.tel.end(
                    telemetry::slot_tid(slot),
                    "request",
                    seq.id,
                    match finish {
                        FinishReason::Eos => telemetry::FINISH_EOS,
                        FinishReason::Length => telemetry::FINISH_LENGTH,
                        FinishReason::Failed { .. } => telemetry::FINISH_FAILED,
                        FinishReason::Deadline => telemetry::FINISH_DEADLINE,
                        FinishReason::Preempted { .. } => telemetry::FINISH_PREEMPTED,
                    },
                );
                sink.complete(Completion {
                    id: seq.id,
                    slot,
                    prompt_len: seq.prompt_len,
                    generated: seq.generated,
                    finish,
                    queued_steps: seq.admitted_step - seq.enqueued_step,
                    decode_steps: self.step_idx + 1 - seq.admitted_step,
                    tokens: seq.tokens,
                });
            }
        }
        self.stats.tokens_sampled += sampled;
        self.engine.note_generated(sampled);

        // 3a. KV reservation: every live slot must cover its upcoming
        // decode rows BEFORE the dispatch writes them (the lazy paged
        // pool's write-before-advance contract). A slot the pool cannot
        // grow — even after LRU eviction — is PREEMPTED: its pages return
        // to the pool and the request requeues for a from-scratch
        // recompute through the same backoff path a prefill fault takes
        // (deterministic per-request streams make the replay
        // bit-identical). Reservation runs in slot index order, so the
        // victim set is deterministic. Engines without lazy growth keep
        // the default always-true reserve and never preempt.
        for slot in 0..b {
            let need = match &self.slots[slot] {
                // Chunked ticks write up to min(N, quota) rows; stepwise
                // writes exactly 1. Live slots always hold quota >= 1.
                Some(seq) => self.chunk.min(seq.max_new - seq.generated).max(1),
                None => continue,
            };
            if !self.engine.reserve_decode(slot, need)? {
                retired += self.preempt_slot(slot, sink)?;
            }
        }

        // 3b. One fused decode over every still-live slot, each at its own
        // position: the fed token's cache row is `pad + index`, and the
        // slot's valid start (= pad) rides along so the artifact masks the
        // left-padding out of attention. Free slots ride along as dead
        // rows (PAD at pos 0, start 0).
        let active_n = self.n_active();
        if active_n > 0 {
            for slot in 0..b {
                if let Some(seq) = &self.slots[slot] {
                    let Some(&last) = seq.tokens.last() else {
                        bail!(
                            "scheduler invariant violated: slot {slot} (request {}) holds \
                             an empty token buffer at step {}",
                            seq.id,
                            self.step_idx
                        );
                    };
                    self.step_toks[slot] = last;
                    self.step_pos[slot] = (seq.pad + seq.tokens.len() - 1) as i32;
                    self.step_starts[slot] = seq.pad as i32;
                    self.step_active[slot] = true;
                    let w = seed_words(seq.device_seed);
                    self.step_seeds[2 * slot] = w[0];
                    self.step_seeds[2 * slot + 1] = w[1];
                    self.step_steps[slot] = seq.generated as i32;
                    self.step_quota[slot] = (seq.max_new - seq.generated) as i32;
                } else {
                    self.step_toks[slot] = Vocab::PAD;
                    self.step_pos[slot] = 0;
                    self.step_starts[slot] = 0;
                    self.step_active[slot] = false;
                    self.step_seeds[2 * slot] = 0;
                    self.step_seeds[2 * slot + 1] = 0;
                    self.step_steps[slot] = 0;
                    self.step_quota[slot] = 0;
                }
            }
            if self.chunk > 1 {
                retired += self.chunk_decode(dev_params.unwrap_or_default(), sink)?;
            } else {
                // Bounded retry with identical inputs: a transient fault that
                // fired before the engine touched per-slot state recovers
                // bit-identically, because this tick's sampling already read
                // the pending rows of the last SUCCESSFUL call and no RNG
                // stream advances for a failed attempt.
                let mut attempt = 0u32;
                let batch = DecodeBatch {
                    toks: &self.step_toks,
                    pos: &self.step_pos,
                    starts: &self.step_starts,
                    active: &self.step_active,
                    traffic,
                    rng: device.then(|| DecodeRng {
                        seeds: &self.step_seeds,
                        steps: &self.step_steps,
                        sparams: dev_params.unwrap_or_default(),
                    }),
                };
                self.tel
                    .begin(telemetry::TID_ENGINE, "decode", self.step_idx, active_n as i64);
                let out = loop {
                    match self.engine.decode_slots(&batch) {
                        Ok(out) => break Some(out),
                        Err(_) => {
                            self.stats.decode_faults += 1;
                            self.tel.instant(
                                telemetry::TID_ENGINE,
                                "decode_retry",
                                self.step_idx,
                                (attempt + 1) as i64,
                            );
                            if attempt >= self.policy.max_retries {
                                break None;
                            }
                            attempt += 1;
                            self.stats.decode_retries += 1;
                        }
                    }
                };
                self.tel.end(
                    telemetry::TID_ENGINE,
                    "decode",
                    self.step_idx,
                    if out.is_some() { 1 } else { 0 },
                );
                match out {
                    Some(out) => {
                        for slot in 0..b {
                            if let Some(seq) = self.slots[slot].as_mut() {
                                seq.pending.copy_from(out.row(slot));
                            }
                        }
                        self.stats.decode_calls += 1;
                        self.stats.slot_steps_active += active_n as u64;
                        self.stats.slot_steps_total += b as u64;
                    }
                    None => retired += self.retire_all_failed(attempt, sink),
                }
            }
        }

        self.step_idx += self.chunk as u64;
        Ok(retired)
    }

    /// KV-pool exhaustion took `slot`'s next page: release the sequence's
    /// pages and requeue the request it came from for a from-scratch
    /// recompute (generated tokens are DISCARDED — per-request streams
    /// replay them bit-identically on readmission), mirroring the
    /// prefill-fault requeue: the aborted request span closes, a
    /// `preempt` instant marks the cause, and the queued span re-opens
    /// with backoff. Past the shared [`FaultPolicy::max_retries`] budget
    /// the request retires as [`FinishReason::Preempted`] with whatever
    /// it generated before losing its pages. Returns how many retired
    /// (0 or 1).
    fn preempt_slot(&mut self, slot: usize, sink: &mut dyn CompletionSink) -> Result<usize> {
        let Some(seq) = self.slots[slot].take() else {
            bail!(
                "scheduler invariant violated: slot {slot} vanished at preemption (step {})",
                self.step_idx
            );
        };
        self.engine.release_slot(slot)?;
        self.stats.preemptions += 1;
        let attempts = seq.attempts + 1;
        if attempts > self.policy.max_retries {
            self.stats.completed += 1;
            self.stats.retired_preempted += 1;
            self.tel.end(
                telemetry::slot_tid(slot),
                "request",
                seq.id,
                telemetry::FINISH_PREEMPTED,
            );
            sink.complete(Completion {
                id: seq.id,
                slot,
                prompt_len: seq.prompt_len,
                generated: seq.generated,
                finish: FinishReason::Preempted { preemptions: attempts },
                queued_steps: seq.admitted_step - seq.enqueued_step,
                decode_steps: self.step_idx + 1 - seq.admitted_step,
                tokens: seq.tokens,
            });
            return Ok(1);
        }
        self.stats.requeues += 1;
        if self.tel.is_enabled() {
            self.tel.end(
                telemetry::slot_tid(slot),
                "request",
                seq.id,
                telemetry::FINISH_ABORTED,
            );
            self.tel
                .instant(telemetry::TID_QUEUE, "preempt", seq.id, attempts as i64);
            self.tel
                .begin(telemetry::TID_QUEUE, "queued", seq.id, attempts as i64);
        }
        self.queue.push_back(Queued {
            req: Request {
                id: seq.id,
                prompt: seq.tokens[..seq.prompt_len].to_vec(),
                max_new: seq.max_new,
                prefix_len: seq.prefix_len,
                seed: seq.seed,
            },
            enqueued_step: seq.enqueued_step,
            not_before: self.step_idx + self.policy.backoff_steps.max(1),
            attempts,
            t_submit_us: seq.t_submit_us,
        });
        self.stats.peak_queue_depth = self.stats.peak_queue_depth.max(self.queue.len());
        Ok(0)
    }

    /// Retry budget exhausted: retire every live sequence with the tokens
    /// it already has, so the queue (and the serve loop) survive the
    /// broken tick.
    fn retire_all_failed(&mut self, attempt: u32, sink: &mut dyn CompletionSink) -> usize {
        let mut retired = 0usize;
        for slot in 0..self.slots.len() {
            let Some(seq) = self.slots[slot].take() else {
                continue;
            };
            let _ = self.engine.release_slot(slot);
            self.stats.completed += 1;
            self.stats.retired_failed += 1;
            retired += 1;
            self.tel.end(
                telemetry::slot_tid(slot),
                "request",
                seq.id,
                telemetry::FINISH_FAILED,
            );
            sink.complete(Completion {
                id: seq.id,
                slot,
                prompt_len: seq.prompt_len,
                generated: seq.generated,
                finish: FinishReason::Failed { retries: attempt },
                queued_steps: seq.admitted_step - seq.enqueued_step,
                decode_steps: self.step_idx + 1 - seq.admitted_step,
                tokens: seq.tokens,
            });
        }
        retired
    }

    /// One fused N-token decode call over every live slot. The engine
    /// latches each row at its first EOS (and after its budget runs dry),
    /// so the scheduler walks each row's prefix up to the first terminal
    /// token: everything before it lands in `tokens` immediately, the
    /// terminal token itself becomes the pending row, and the NEXT tick's
    /// unchanged sample/retire phase pushes it and retires on EOS/Length —
    /// exactly the retirement cadence of stepwise decode, observed every
    /// N steps instead of every step.
    fn chunk_decode(
        &mut self,
        sparams: [f32; 3],
        sink: &mut dyn CompletionSink,
    ) -> Result<usize> {
        let b = self.slots.len();
        let n = self.chunk;
        let batch = ChunkBatch {
            toks: &self.step_toks,
            pos: &self.step_pos,
            active: &self.step_active,
            n,
            quota: &self.step_quota,
            rng: DecodeRng {
                seeds: &self.step_seeds,
                steps: &self.step_steps,
                sparams,
            },
        };
        // Same bounded-retry contract as stepwise: device RNG draws are a
        // pure function of (seed, step, slot), so a retried chunk replays
        // bit-identically.
        let mut attempt = 0u32;
        let active_n = self.step_active.iter().filter(|a| **a).count();
        self.tel
            .begin(telemetry::TID_ENGINE, "decode", self.step_idx, active_n as i64);
        let out = loop {
            match self.engine.decode_slots_chunk(&batch) {
                Ok(ids) => break Some(ids),
                Err(_) => {
                    self.stats.decode_faults += 1;
                    self.tel.instant(
                        telemetry::TID_ENGINE,
                        "decode_retry",
                        self.step_idx,
                        (attempt + 1) as i64,
                    );
                    if attempt >= self.policy.max_retries {
                        break None;
                    }
                    attempt += 1;
                    self.stats.decode_retries += 1;
                }
            }
        };
        self.tel.end(
            telemetry::TID_ENGINE,
            "decode",
            self.step_idx,
            if out.is_some() { 1 } else { 0 },
        );
        match out {
            Some(ids) => {
                if ids.len() != n * b {
                    bail!(
                        "decode_slots_chunk returned {} ids, wanted [{n}, {b}]",
                        ids.len()
                    );
                }
                let (mut consumed_total, mut pushed, mut waste) = (0u64, 0u64, 0u64);
                for slot in 0..b {
                    let Some(seq) = self.slots[slot].as_mut() else {
                        continue;
                    };
                    let quota = self.step_quota[slot].max(0) as usize;
                    let consumed = chunk_consumed(&ids, b, slot, n, quota);
                    if consumed == 0 {
                        // Live slots always enter a chunk with quota >= 1
                        // (generated < max_new, or phase 2 retired them) —
                        // a zero-consumption row here means the walk was
                        // about to read frozen filler as real tokens.
                        bail!(
                            "scheduler invariant violated: live slot {slot} (request {}) \
                             entered a chunk with zero quota at step {}",
                            seq.id,
                            self.step_idx
                        );
                    }
                    let was_generated = seq.generated;
                    for j in 0..consumed - 1 {
                        seq.tokens.push(ids[j * b + slot]);
                        seq.generated += 1;
                        pushed += 1;
                    }
                    let pushed_here = consumed - 1;
                    if self.tel.is_enabled() && pushed_here > 0 {
                        // The chunk lands its tokens in one batch: observed
                        // inter-token latency is the amortized chunk wall
                        // time, recorded once per token it covers. When the
                        // chunk contains the request's first token, that
                        // token's gap is TTFT (recorded below, not an
                        // InterToken sample), so the wall time amortizes
                        // over the remaining pushed_here - 1 samples.
                        let now = self.tel.now_us();
                        let n_inter = pushed_here - usize::from(was_generated == 0);
                        let dt = if n_inter > 0 {
                            now.saturating_sub(seq.t_last_tok_us) / n_inter as u64
                        } else {
                            0
                        };
                        for k in 0..pushed_here {
                            if was_generated == 0 && k == 0 {
                                self.tel.instant(
                                    telemetry::slot_tid(slot),
                                    "first_token",
                                    seq.id,
                                    seq.tokens[seq.prompt_len] as i64,
                                );
                                self.tel
                                    .record(Hist::Ttft, now.saturating_sub(seq.t_submit_us));
                            } else {
                                self.tel.record(Hist::InterToken, dt);
                            }
                        }
                        seq.t_last_tok_us = now;
                    }
                    seq.pending.copy_from(RowRef::Id(ids[(consumed - 1) * b + slot]));
                    consumed_total += consumed as u64;
                    waste += (n - consumed) as u64;
                }
                self.stats.decode_calls += 1;
                self.stats.slot_steps_active += consumed_total;
                self.stats.slot_steps_total += (n * b) as u64;
                self.stats.chunk_waste_tokens += waste;
                self.stats.tokens_sampled += pushed;
                self.engine.note_generated(pushed);
                Ok(0)
            }
            None => Ok(self.retire_all_failed(attempt, sink)),
        }
    }

    /// Drive the loop until queue and slots drain; returns all completions
    /// in retirement order.
    pub fn run_until_idle(
        &mut self,
        backend: &mut dyn SamplingBackend,
    ) -> Result<Vec<Completion>> {
        let mut all = Vec::new();
        while !self.is_idle() {
            all.extend(self.step(backend)?);
        }
        Ok(all)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampling::{DeviceTopK, HostFullRow, SamplerConfig};

    const VOCAB: usize = 32;
    const SP: usize = 4;
    const SG: usize = 8;
    const CONTENT: i32 = 9;

    /// Scripted slot engine: a request's `prompt[0]` encodes how many
    /// content tokens it emits before EOS (`>= SG` means "never EOS"), so
    /// a greedy sampler replays the plan deterministically. Honors every
    /// traffic class — full logits rows, device-argmax ids, or top-k
    /// candidate rows — so the scheduler × backend pairings are testable
    /// without artifacts. Prompts of any length `1..=SP` are accepted
    /// (the padded-admission contract); the true length of every
    /// admission is logged for the mixed-length tests.
    struct MockEngine {
        n_slots: usize,
        /// Whether short prompts are admissible (artifact capability).
        padded: bool,
        /// Paged mode: front-aligned prompts + a scripted prefix registry.
        paged: bool,
        /// Per slot: (planned generated tokens, cursor of the next logits,
        /// admitted prompt's true length).
        plans: Vec<Option<(Vec<i32>, usize, usize)>>,
        /// Scripted shared-prefix registry: token runs seen by earlier
        /// admissions (paged mode only; whole declared prefixes, no page
        /// alignment — alignment is the ledger's concern, exercised in
        /// `hybrid::kv`).
        prefixes: std::collections::HashSet<Vec<i32>>,
        prefill_log: Vec<usize>,
        /// True prompt length of every admission, in admission order.
        prefill_lens: Vec<usize>,
        released: Vec<usize>,
        /// Active-mask of every decode call (for utilization assertions).
        decode_active: Vec<Vec<bool>>,
        /// Valid-start vector of every decode call (padding assertions).
        decode_starts: Vec<Vec<i32>>,
        /// Traffic class of every decode call (artifact-family assertions).
        decode_traffic: Vec<TrafficClass>,
        /// Derive content tokens from the batch's device-RNG inputs instead
        /// of the scripted constant — a pure function of (seed, draw index),
        /// like the real `decode_*_rng` artifacts — so stream-determinism
        /// across admission orderings and chunk sizes is observable.
        device_rng: bool,
        /// Per slot: upcoming `reserve_decode` calls to refuse (scripted
        /// KV-pool exhaustion; the preemption-path tests' pressure knob).
        reserve_denials: Vec<u32>,
        /// `can_admit` refuses while this many slots are live (scripted
        /// pool-capacity gate; `None` = always admissible).
        admit_cap: Option<usize>,
    }

    impl MockEngine {
        fn new(n_slots: usize) -> Self {
            MockEngine {
                n_slots,
                padded: true,
                paged: false,
                plans: (0..n_slots).map(|_| None).collect(),
                prefixes: std::collections::HashSet::new(),
                prefill_log: Vec::new(),
                prefill_lens: Vec::new(),
                released: Vec::new(),
                decode_active: Vec::new(),
                decode_starts: Vec::new(),
                decode_traffic: Vec::new(),
                device_rng: false,
                reserve_denials: vec![0; n_slots],
                admit_cap: None,
            }
        }

        /// Refuse the next `k` `reserve_decode` calls on `slot` (scripted
        /// pool exhaustion — each refusal preempts the slot's sequence).
        fn deny_reserves(mut self, slot: usize, k: u32) -> Self {
            self.reserve_denials[slot] = k;
            self
        }

        /// Refuse admissions while `cap` slots are live (scripted
        /// KV-capacity gate for the deferral tests).
        fn admit_cap(mut self, cap: usize) -> Self {
            self.admit_cap = Some(cap);
            self
        }

        /// A pre-capability engine: only exact-length prompts admissible.
        fn without_padded(mut self) -> Self {
            self.padded = false;
            self
        }

        /// A block-paged engine: front-aligned prompts, prefix reuse.
        fn paged_mode(mut self) -> Self {
            self.paged = true;
            self.padded = false; // paged serving needs no left-pad masks
            self
        }

        /// Content tokens become counter-RNG draws (see `device_rng`).
        fn device_rng_mode(mut self) -> Self {
            self.device_rng = true;
            self
        }

        /// The mock's device draw: Threefry-keyed by the slot's seed words
        /// and the draw index, mapped into content-token space (never EOS,
        /// never PAD) — slot-placement-independent like the real kernel.
        fn rng_token(k0: i32, k1: i32, step: u32) -> i32 {
            let (x0, _) = crate::sampling::threefry2x32(k0 as u32, k1 as u32, step, 0);
            10 + ((x0 >> 8) % 16) as i32
        }

        fn logits_for(&self, tok: i32) -> Vec<f32> {
            let mut row = vec![0.0f32; VOCAB];
            row[tok as usize] = 1.0;
            row
        }

        /// The scripted next token as one pending row of class `traffic`.
        fn row_for(&self, tok: i32, traffic: TrafficClass) -> PendingRow {
            match traffic {
                TrafficClass::FullRow => PendingRow::Logits(self.logits_for(tok)),
                TrafficClass::DeviceIds => PendingRow::Id(tok),
                TrafficClass::DeviceTopK => {
                    // Two candidates, scripted token dominant and sorted
                    // first (the device tail's descending order).
                    let other = (tok + 1) % VOCAB as i32;
                    PendingRow::TopK { vals: vec![10.0, -10.0], ids: vec![tok, other] }
                }
                // The device drew the token itself; only the id crosses.
                TrafficClass::DeviceCategorical => PendingRow::Id(tok),
            }
        }
    }

    impl SlotEngine for MockEngine {
        fn n_slots(&self) -> usize {
            self.n_slots
        }

        fn prompt_len(&self) -> usize {
            SP
        }

        fn max_new_tokens(&self) -> usize {
            SG
        }

        fn supports_padded_prompts(&self) -> bool {
            self.padded
        }

        fn paged(&self) -> bool {
            self.paged
        }

        fn prefill_slot(&mut self, slot: usize, adm: &Admission) -> Result<AdmitOutcome> {
            let prompt = adm.prompt;
            assert!(!prompt.is_empty() && prompt.len() <= SP, "{}", prompt.len());
            assert!(
                self.padded || self.paged || prompt.len() == SP,
                "short prompt without capability"
            );
            assert!(self.plans[slot].is_none(), "prefill into busy slot {slot}");
            let mut reused = 0usize;
            if self.paged && adm.prefix_len > 0 {
                let key = prompt[..adm.prefix_len].to_vec();
                if self.prefixes.contains(&key) {
                    reused = adm.prefix_len;
                } else {
                    self.prefixes.insert(key);
                }
            }
            let n = prompt[0] as usize;
            let plan: Vec<i32> = (0..SG + 2)
                .map(|j| if j < n { CONTENT } else { Vocab::EOS })
                .collect();
            let mut first = plan[0];
            if adm.traffic == TrafficClass::DeviceCategorical {
                let rng = adm.rng.expect("device admission without rng inputs");
                // Prefill performs draw #0 of the request's stream.
                if self.device_rng && first != Vocab::EOS {
                    first = Self::rng_token(rng.seed[0], rng.seed[1], 0);
                }
            }
            let row = self.row_for(first, adm.traffic);
            self.plans[slot] = Some((plan, 1, prompt.len()));
            self.prefill_log.push(slot);
            self.prefill_lens.push(prompt.len());
            Ok(AdmitOutcome { pending: row, reused_tokens: reused, prefix_hit: reused > 0 })
        }

        fn decode_slots(&mut self, batch: &DecodeBatch) -> Result<SampleOut> {
            let (toks, pos, starts, active) = (batch.toks, batch.pos, batch.starts, batch.active);
            let traffic = batch.traffic;
            assert_eq!(toks.len(), self.n_slots);
            assert_eq!(pos.len(), self.n_slots);
            assert_eq!(starts.len(), self.n_slots);
            self.decode_active.push(active.to_vec());
            self.decode_starts.push(starts.to_vec());
            self.decode_traffic.push(traffic);
            let mut next = vec![0i32; self.n_slots];
            for slot in 0..self.n_slots {
                if !active[slot] {
                    continue;
                }
                let (plan, cur, true_len) = self.plans[slot].as_mut().expect("active free slot");
                if self.paged {
                    // The front-alignment contract: no left-padding ever,
                    // and the fed position is the sequence's true depth.
                    assert_eq!(starts[slot], 0, "slot {slot} paged start");
                    assert_eq!(
                        pos[slot] as usize,
                        *true_len + *cur - 1,
                        "slot {slot} fed off its depth (paged)"
                    );
                } else {
                    // The padding contract: the slot's valid start must be
                    // the left-pad width of its admitted prompt, and the
                    // fed position the pad-offset cache row of its newest
                    // token.
                    assert_eq!(starts[slot] as usize, SP - *true_len, "slot {slot} start");
                    assert_eq!(
                        pos[slot] as usize,
                        SP + *cur - 1,
                        "slot {slot} fed off its depth"
                    );
                }
                let step = *cur;
                next[slot] = plan[step];
                *cur += 1;
                if traffic == TrafficClass::DeviceCategorical {
                    let rng = batch.rng.expect("device decode without rng inputs");
                    // The scheduler's stream bookkeeping: this call performs
                    // draw #cur of the slot's request, no matter the batch
                    // composition around it.
                    assert_eq!(rng.steps[slot] as usize, step, "slot {slot} draw index");
                    if self.device_rng && next[slot] != Vocab::EOS {
                        next[slot] = Self::rng_token(
                            rng.seeds[2 * slot],
                            rng.seeds[2 * slot + 1],
                            step as u32,
                        );
                    }
                }
            }
            Ok(match traffic {
                TrafficClass::FullRow => {
                    let mut data = vec![0.0f32; self.n_slots * VOCAB];
                    for slot in 0..self.n_slots {
                        if active[slot] {
                            let row = self.logits_for(next[slot]);
                            data[slot * VOCAB..(slot + 1) * VOCAB].copy_from_slice(&row);
                        }
                    }
                    SampleOut::Logits { data, vocab: VOCAB }
                }
                TrafficClass::DeviceIds | TrafficClass::DeviceCategorical => {
                    SampleOut::Ids(next)
                }
                TrafficClass::DeviceTopK => {
                    let mut vals = Vec::with_capacity(self.n_slots * 2);
                    let mut ids = Vec::with_capacity(self.n_slots * 2);
                    for &t in &next {
                        vals.extend_from_slice(&[10.0, -10.0]);
                        ids.extend_from_slice(&[t, (t + 1) % VOCAB as i32]);
                    }
                    SampleOut::TopK { vals, ids, k: 2 }
                }
            })
        }

        fn check_decode_chunk(&self, n: usize) -> Result<()> {
            if n <= 1 || self.paged {
                Ok(())
            } else {
                bail!("mock engine: fused decode chunks serve from paged mode only")
            }
        }

        fn decode_slots_chunk(&mut self, batch: &ChunkBatch) -> Result<Vec<i32>> {
            assert!(self.paged, "chunk decode on a non-paged mock");
            assert!(batch.n >= 2, "n == 1 is the stepwise path");
            let (b, n) = (self.n_slots, batch.n);
            assert_eq!(batch.toks.len(), b);
            self.decode_active.push(batch.active.to_vec());
            self.decode_traffic.push(TrafficClass::DeviceCategorical);
            // Frozen rows emit EOS filler, like the real kernel's latch.
            let mut ids = vec![Vocab::EOS; n * b];
            for slot in 0..b {
                if !batch.active[slot] {
                    continue;
                }
                let (plan, cur, true_len) =
                    self.plans[slot].as_mut().expect("active free slot");
                assert_eq!(
                    batch.pos[slot] as usize,
                    *true_len + *cur - 1,
                    "slot {slot} fed off its depth (chunk)"
                );
                let rng = &batch.rng;
                assert_eq!(
                    rng.steps[slot] as usize,
                    *cur,
                    "slot {slot} chunk base draw index"
                );
                let mut quota = batch.quota[slot];
                assert!(quota >= 1, "live slot {slot} entered a chunk with no budget");
                for j in 0..n {
                    let step = *cur;
                    let mut tok = plan[step];
                    if self.device_rng && tok != Vocab::EOS {
                        tok = Self::rng_token(
                            rng.seeds[2 * slot],
                            rng.seeds[2 * slot + 1],
                            step as u32,
                        );
                    }
                    ids[j * b + slot] = tok;
                    *cur += 1;
                    quota -= 1;
                    if tok == Vocab::EOS || quota <= 0 {
                        break; // latched: the rest of the row stays filler
                    }
                }
            }
            Ok(ids)
        }

        fn can_admit(&self, _prompt: &[i32], _prefix_len: usize) -> bool {
            match self.admit_cap {
                Some(cap) => self.plans.iter().filter(|p| p.is_some()).count() < cap,
                None => true,
            }
        }

        fn reserve_decode(&mut self, slot: usize, n: usize) -> Result<bool> {
            assert!(self.plans[slot].is_some(), "reserve on free slot {slot}");
            assert!(n >= 1, "reserve_decode of zero rows on slot {slot}");
            if self.reserve_denials[slot] > 0 {
                self.reserve_denials[slot] -= 1;
                return Ok(false);
            }
            Ok(true)
        }

        fn release_slot(&mut self, slot: usize) -> Result<()> {
            assert!(self.plans[slot].is_some(), "release of free slot {slot}");
            self.plans[slot] = None;
            self.released.push(slot);
            Ok(())
        }
    }

    fn greedy() -> HostFullRow {
        HostFullRow::new(SamplerConfig { greedy: true, ..Default::default() }, 0)
    }

    fn device_greedy() -> DeviceTopK {
        DeviceTopK::new(SamplerConfig { greedy: true, ..Default::default() }, 0, 2, VOCAB)
            .unwrap()
    }

    /// Device-RNG backend, greedy flavor (temperature-0 device draw).
    fn device_cat() -> crate::sampling::DeviceCategorical {
        crate::sampling::DeviceCategorical::new(
            SamplerConfig { greedy: true, ..Default::default() },
            2,
            VOCAB,
        )
        .unwrap()
    }

    /// Device-RNG backend, stochastic flavor.
    fn device_cat_stochastic() -> crate::sampling::DeviceCategorical {
        crate::sampling::DeviceCategorical::new(SamplerConfig::default(), 2, VOCAB).unwrap()
    }

    /// `prompt[0]` = content tokens the scripted engine emits before EOS.
    fn req(id: u64, eos_after: i32, max_new: usize) -> Request {
        let mut prompt = vec![CONTENT; SP];
        prompt[0] = eos_after;
        Request { id, prompt, max_new, seed: None, prefix_len: 0 }
    }

    #[test]
    fn admission_happens_at_step_boundaries_only() {
        let mut sched = Scheduler::new(MockEngine::new(2)).unwrap();
        let mut sampler = greedy();
        for id in 0..3 {
            sched.submit(req(id, 100, 3)).unwrap();
        }
        // Tick 1: both slots admitted, third request queued.
        sched.step(&mut sampler).unwrap();
        assert_eq!(sched.engine.prefill_log, vec![0, 1]);
        assert_eq!(sched.queue_depth(), 1);
        assert_eq!(sched.n_active(), 2);
        // Ticks 2-3: slots stay busy, no mid-flight admission even though
        // both retire during tick 3.
        sched.step(&mut sampler).unwrap();
        let done = sched.step(&mut sampler).unwrap();
        assert_eq!(done.len(), 2, "both length-capped sequences retire together");
        assert_eq!(sched.engine.prefill_log.len(), 2, "no admission before the boundary");
        // Tick 4: the queued request takes the first freed slot.
        sched.step(&mut sampler).unwrap();
        assert_eq!(sched.engine.prefill_log, vec![0, 1, 0]);
        assert_eq!(sched.queue_depth(), 0);
        assert_eq!(sched.n_active(), 1);
    }

    #[test]
    fn slot_is_reused_after_retirement() {
        let mut sched = Scheduler::new(MockEngine::new(1)).unwrap();
        let mut sampler = greedy();
        sched.submit(req(7, 1, SG)).unwrap();
        sched.submit(req(8, 1, SG)).unwrap();
        let all = sched.run_until_idle(&mut sampler).unwrap();
        assert_eq!(all.len(), 2);
        assert_eq!(all[0].id, 7);
        assert_eq!(all[1].id, 8);
        // Same slot served both sequences, back to back.
        assert_eq!(sched.engine.prefill_log, vec![0, 0]);
        assert_eq!(sched.engine.released, vec![0, 0]);
        assert_eq!(all[1].slot, 0);
    }

    #[test]
    fn eos_and_length_retirement() {
        let mut sched = Scheduler::new(MockEngine::new(2)).unwrap();
        let mut sampler = greedy();
        sched.submit(req(1, 2, SG)).unwrap(); // C C EOS
        sched.submit(req(2, 100, 4)).unwrap(); // never EOS, capped at 4
        let all = sched.run_until_idle(&mut sampler).unwrap();
        assert_eq!(all.len(), 2);
        let a = all.iter().find(|c| c.id == 1).unwrap();
        let b = all.iter().find(|c| c.id == 2).unwrap();
        assert_eq!(a.finish, FinishReason::Eos);
        assert_eq!(a.generated, 3);
        assert_eq!(a.response(), &[CONTENT, CONTENT, Vocab::EOS]);
        assert_eq!(b.finish, FinishReason::Length);
        assert_eq!(b.generated, 4);
        assert_eq!(b.response(), &[CONTENT; 4]);
        assert!(b.response().iter().all(|&t| t != Vocab::EOS));
    }

    #[test]
    fn backpressure_queues_when_all_slots_busy() {
        let mut sched = Scheduler::new(MockEngine::new(2)).unwrap();
        let mut sampler = greedy();
        for id in 0..5 {
            sched.submit(req(id, 100, 2)).unwrap();
        }
        sched.step(&mut sampler).unwrap();
        assert_eq!(sched.stats.admitted, 2);
        assert_eq!(sched.queue_depth(), 3);
        assert_eq!(sched.stats.peak_queue_depth, 5);
        let all = sched.run_until_idle(&mut sampler).unwrap();
        assert_eq!(all.len(), 5, "every request eventually completes");
        assert_eq!(sched.stats.completed, 5);
        // The first wave never queued; the later waves did.
        for c in &all {
            if c.id < 2 {
                assert_eq!(c.queued_steps, 0, "req {}", c.id);
            } else {
                assert!(c.queued_steps > 0, "req {}", c.id);
            }
        }
        // No decode call ever carried more live slots than exist.
        for mask in &sched.engine.decode_active {
            assert!(mask.iter().filter(|a| **a).count() <= 2);
        }
        assert!(sched.is_idle());
        assert!(sched.stats.utilization() > 0.5);
    }

    #[test]
    fn wrong_prompt_length_is_rejected_at_submit() {
        let mut sched = Scheduler::new(MockEngine::new(1)).unwrap();
        let err = sched
            .submit(Request { id: 0, prompt: vec![1; SP + 1], max_new: 4, seed: None, prefix_len: 0 })
            .unwrap_err();
        assert!(format!("{err:#}").contains("prompt must be"));
        let err = sched
            .submit(Request { id: 1, prompt: vec![], max_new: 4, seed: None, prefix_len: 0 })
            .unwrap_err();
        assert!(format!("{err:#}").contains("prompt must be"));
        // A declared shared prefix must fit inside the prompt.
        let err = sched
            .submit(Request { id: 2, prompt: vec![1; SP], max_new: 4, seed: None, prefix_len: SP + 1 })
            .unwrap_err();
        assert!(format!("{err:#}").contains("shared prefix"), "{err:#}");
        assert!(sched.is_idle());
    }

    /// `prompt[0]` = scripted content count, with an explicit TRUE length.
    fn req_len(id: u64, eos_after: i32, max_new: usize, len: usize) -> Request {
        let mut prompt = vec![CONTENT; len];
        prompt[0] = eos_after;
        Request { id, prompt, max_new, seed: None, prefix_len: 0 }
    }

    #[test]
    fn short_prompts_need_engine_capability() {
        // A pre-capability engine (no valid-start masks in its artifacts)
        // must reject short prompts at SUBMIT time with the rebuild
        // command, while exact-length traffic keeps working.
        let mut sched = Scheduler::new(MockEngine::new(1).without_padded()).unwrap();
        let err = sched.submit(req_len(0, 1, 4, SP - 1)).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("make artifacts"), "{msg}");
        assert!(msg.contains("padded_prompts"), "{msg}");
        assert!(sched.is_idle());
        sched.submit(req(1, 1, 4)).unwrap();
        let done = sched.run_until_idle(&mut greedy()).unwrap();
        assert_eq!(done.len(), 1);
    }

    #[test]
    fn mixed_length_prompts_coexist_and_count_valid_tokens() {
        // A short and a full-length prompt share the batch: the engine
        // sees each slot's true valid start on every decode call, pad
        // entries are never sampled, and the stats count valid prompt
        // tokens and pad overhead separately.
        let mut sched = Scheduler::new(MockEngine::new(2)).unwrap();
        let mut sampler = greedy();
        sched.submit(req_len(0, 100, 3, 2)).unwrap(); // short: pad SP-2
        sched.submit(req_len(1, 100, 3, SP)).unwrap(); // exact length
        let mut done = sched.run_until_idle(&mut sampler).unwrap();
        done.sort_by_key(|c| c.id);
        assert_eq!(done.len(), 2);
        assert_eq!(done[0].prompt_len, 2, "true length survives to the completion");
        assert_eq!(done[1].prompt_len, SP);
        // Completions carry TRUE tokens only: prompt ++ generated, no pads.
        assert_eq!(done[0].tokens.len(), 2 + 3);
        assert_eq!(done[0].response(), &[CONTENT; 3]);
        assert_eq!(done[1].tokens.len(), SP + 3);
        let eng = &sched.engine;
        assert_eq!(eng.prefill_lens, vec![2, SP]);
        // Both slots decoded side by side with their own valid starts.
        for (mask, starts) in eng.decode_active.iter().zip(&eng.decode_starts) {
            if mask[0] {
                assert_eq!(starts[0] as usize, SP - 2);
            }
            if mask[1] {
                assert_eq!(starts[1], 0);
            }
        }
        let st = &sched.stats;
        assert_eq!(st.prompt_tokens, (2 + SP) as u64);
        assert_eq!(st.pad_tokens, (SP - 2) as u64);
        let want = (SP - 2) as f64 / (2 * SP) as f64;
        assert!((st.pad_fraction() - want).abs() < 1e-12, "{}", st.pad_fraction());
        // Sampled tokens are the VALID generated tokens only.
        assert_eq!(st.tokens_sampled, 6);
    }

    #[test]
    fn exact_length_traffic_has_zero_pad_overhead() {
        let mut sched = Scheduler::new(MockEngine::new(2)).unwrap();
        sched.submit(req(0, 1, SG)).unwrap();
        sched.submit(req(1, 2, SG)).unwrap();
        sched.run_until_idle(&mut greedy()).unwrap();
        assert_eq!(sched.stats.pad_tokens, 0);
        assert_eq!(sched.stats.pad_fraction(), 0.0);
        assert!(sched.engine.decode_starts.iter().flatten().all(|&s| s == 0));
    }

    #[test]
    fn eos_retire_then_readmit_with_different_length_successor() {
        // One slot serves three requests of three different lengths back
        // to back; each admission re-establishes its own pad, and the
        // scripted plans replay correctly at every length.
        let mut sched = Scheduler::new(MockEngine::new(1)).unwrap();
        let mut sampler = greedy();
        sched.submit(req_len(0, 1, SG, 3)).unwrap(); // C EOS
        sched.submit(req_len(1, 2, SG, SP)).unwrap(); // C C EOS
        sched.submit(req_len(2, 1, SG, 1)).unwrap(); // C EOS (1-token prompt)
        let done = sched.run_until_idle(&mut sampler).unwrap();
        assert_eq!(done.len(), 3);
        assert_eq!(sched.engine.prefill_lens, vec![3, SP, 1]);
        assert_eq!(sched.engine.prefill_log, vec![0, 0, 0], "same slot, reused");
        for (c, (want_plen, want_gen)) in done.iter().zip([(3, 2), (SP, 3), (1, 2)]) {
            assert_eq!(c.prompt_len, want_plen, "req {}", c.id);
            assert_eq!(c.generated, want_gen, "req {}", c.id);
            assert_eq!(c.finish, FinishReason::Eos);
            assert_eq!(c.tokens.len(), want_plen + want_gen);
        }
        assert_eq!(sched.stats.prompt_tokens, (3 + SP + 1) as u64);
        assert_eq!(sched.stats.pad_tokens, ((SP - 3) + (SP - 1)) as u64);
    }

    /// Run one scripted trace to idle under a backend; returns completions
    /// sorted by id plus the engine for traffic-class assertions.
    fn run_trace(backend: &mut dyn SamplingBackend) -> (Vec<Completion>, MockEngine) {
        let mut sched = Scheduler::new(MockEngine::new(2)).unwrap();
        sched.submit(req(0, 2, SG)).unwrap();
        sched.submit(req(1, 100, 5)).unwrap();
        sched.submit(req(2, 3, SG)).unwrap();
        let mut all = sched.run_until_idle(backend).unwrap();
        all.sort_by_key(|c| c.id);
        (all, sched.engine)
    }

    #[test]
    fn device_ids_traffic_reproduces_host_schedule() {
        // The same scripted trace through the host full-row backend and
        // the device-greedy backend must retire identical sequences — the
        // scheduler's policy is traffic-class-invariant, only the bytes
        // moved differ (the O(b)-per-tick device-sampling contract).
        let (host, host_eng) = run_trace(&mut greedy());
        let (dev, dev_eng) = run_trace(&mut device_greedy());
        assert_eq!(host.len(), dev.len());
        for (h, d) in host.iter().zip(&dev) {
            assert_eq!(h.id, d.id);
            assert_eq!(h.tokens, d.tokens, "req {}", h.id);
            assert_eq!(h.finish, d.finish);
            assert_eq!(h.slot, d.slot);
        }
        assert!(host_eng.decode_traffic.iter().all(|t| *t == TrafficClass::FullRow));
        assert!(dev_eng.decode_traffic.iter().all(|t| *t == TrafficClass::DeviceIds));
    }

    #[test]
    fn step_into_streams_completions_and_counts_retirements() {
        // The sink generalization: completions land in the caller's sink
        // the step they retire, and the returned count matches.
        struct Tally {
            ids: Vec<u64>,
        }
        impl CompletionSink for Tally {
            fn complete(&mut self, c: Completion) {
                self.ids.push(c.id);
            }
        }
        let mut sched = Scheduler::new(MockEngine::new(2)).unwrap();
        let mut sampler = greedy();
        sched.submit(req(0, 1, SG)).unwrap(); // C EOS -> retires tick 2
        sched.submit(req(1, 100, 3)).unwrap(); // length-capped at 3
        let mut sink = Tally { ids: Vec::new() };
        let mut per_step = Vec::new();
        while !sched.is_idle() {
            per_step.push(sched.step_into(&mut sampler, &mut sink).unwrap());
        }
        assert_eq!(sink.ids, vec![0, 1]);
        assert_eq!(per_step.iter().sum::<usize>(), 2);
        assert_eq!(sched.stats.retired_eos, 1);
        assert_eq!(sched.stats.retired_length, 1);
        assert_eq!(
            sched.stats.tokens_sampled,
            sched.stats.retired_eos * 2 + 3,
            "every sampled token counted"
        );
    }

    #[test]
    fn bubble_fraction_complements_utilization() {
        let mut sched = Scheduler::new(MockEngine::new(2)).unwrap();
        let mut sampler = greedy();
        assert_eq!(sched.stats.bubble_fraction(), 0.0, "no decode calls yet");
        // One long request on a 2-slot engine: every decode call carries a
        // dead row, so the bubble fraction is exactly 1 - utilization.
        sched.submit(req(0, 100, 4)).unwrap();
        sched.run_until_idle(&mut sampler).unwrap();
        let st = &sched.stats;
        assert!(st.slot_steps_total > 0);
        assert!((st.bubble_fraction() - (1.0 - st.utilization())).abs() < 1e-12);
        assert!(st.bubble_fraction() >= 0.5 - 1e-12, "{}", st.bubble_fraction());
    }

    #[test]
    fn seeded_requests_use_their_own_streams() {
        // MockEngine emits one-hot rows, so to expose the RNG plumbing we
        // sample at high temperature over the scripted logits: a request
        // with a seed must reproduce its solo token sequence even when
        // co-scheduled with other seeded requests (admission-order
        // independence), while the scripted plan pins nothing else.
        let stochastic = || {
            HostFullRow::new(
                SamplerConfig { temperature: 50.0, ..Default::default() },
                1234,
            )
        };
        let run = |reqs: Vec<Request>| -> Vec<Completion> {
            let mut sched = Scheduler::new(MockEngine::new(2)).unwrap();
            for r in reqs {
                sched.submit(r).unwrap();
            }
            let mut all = sched.run_until_idle(&mut stochastic()).unwrap();
            all.sort_by_key(|c| c.id);
            all
        };
        let seeded = |id: u64, seed: u64| Request { seed: Some(seed), ..req(id, 100, 4) };
        let solo = run(vec![seeded(0, 7)]);
        let crowd = run(vec![seeded(0, 7), seeded(1, 8), seeded(2, 9)]);
        assert_eq!(
            solo[0].tokens, crowd[0].tokens,
            "per-request stream must not depend on co-scheduled load"
        );
        // And a different seed gives an (almost surely) different path for
        // the same prompt under the same flat-ish distribution.
        let other = run(vec![seeded(0, 1000)]);
        assert_ne!(solo[0].tokens, other[0].tokens, "seed must steer the stream");
    }

    #[test]
    fn device_topk_traffic_drives_stochastic_backend() {
        // A stochastic DeviceTopK backend over the scripted candidate rows
        // (dominant first candidate) follows the same plan: the scheduler
        // retires on the host-drawn ids, never sees a logits row.
        let cfg = SamplerConfig { temperature: 0.7, top_p: 0.9, ..Default::default() };
        let mut backend = DeviceTopK::new(cfg, 11, 2, VOCAB).unwrap();
        let (done, eng) = run_trace(&mut backend);
        let (host, _) = run_trace(&mut greedy());
        assert_eq!(done.len(), host.len());
        for (d, h) in done.iter().zip(&host) {
            assert_eq!(d.tokens, h.tokens, "req {} (dominant candidate)", d.id);
        }
        assert!(eng.decode_traffic.iter().all(|t| *t == TrafficClass::DeviceTopK));
    }

    #[test]
    fn paged_engine_front_aligns_and_admits_short_prompts() {
        // A paged engine takes short prompts WITHOUT the padded_prompts
        // capability (front alignment needs no valid-start masks), pad
        // accounting stays zero, and every decode position is the true
        // sequence depth (asserted inside the mock).
        let mut sched = Scheduler::new(MockEngine::new(2).paged_mode()).unwrap();
        assert!(!sched.engine.supports_padded_prompts());
        sched.submit(req_len(0, 100, 3, 2)).unwrap(); // short, no capability
        sched.submit(req_len(1, 100, 3, SP)).unwrap();
        let mut done = sched.run_until_idle(&mut greedy()).unwrap();
        done.sort_by_key(|c| c.id);
        assert_eq!(done.len(), 2);
        assert_eq!(done[0].tokens.len(), 2 + 3);
        assert_eq!(done[0].response(), &[CONTENT; 3]);
        let st = &sched.stats;
        assert_eq!(st.pad_tokens, 0, "front alignment never pads");
        assert_eq!(st.pad_fraction(), 0.0);
        assert!(sched.engine.decode_starts.iter().flatten().all(|&s| s == 0));
    }

    #[test]
    fn shared_prefix_reuse_lands_in_the_stats() {
        // Three paged requests share a system prompt (declared via
        // prefix_len); the first admission is the registry miss, the other
        // two hit, and the stats report the admitted-vs-computed saving
        // the serve bench emits. Completions are unaffected by sharing.
        let mut sched = Scheduler::new(MockEngine::new(1).paged_mode()).unwrap();
        let shared: Vec<i32> = vec![2, CONTENT, CONTENT]; // prompt[0]=2 -> C C EOS
        for id in 0..3 {
            let mut prompt = shared.clone();
            prompt.push(10 + id as i32); // unique tail token
            sched
                .submit(Request {
                    id,
                    prompt,
                    max_new: SG,
                    seed: None,
                    prefix_len: shared.len(),
                })
                .unwrap();
        }
        let done = sched.run_until_idle(&mut greedy()).unwrap();
        assert_eq!(done.len(), 3);
        for c in &done {
            assert_eq!(c.response(), &[CONTENT, CONTENT, Vocab::EOS], "req {}", c.id);
        }
        let st = &sched.stats;
        assert_eq!(st.prefix_misses, 1, "first admission registers");
        assert_eq!(st.prefix_hits, 2, "later admissions reuse");
        assert_eq!(st.reused_tokens, 2 * shared.len() as u64);
        assert_eq!(st.admitted_tokens(), 3 * (shared.len() + 1) as u64);
        assert_eq!(
            st.computed_tokens(),
            st.admitted_tokens() - st.reused_tokens,
            "computed = admitted - reused"
        );
        assert!(st.computed_tokens() < st.admitted_tokens());
        let want = 2.0 / 3.0;
        assert!((st.cache_hit_rate() - want).abs() < 1e-12, "{}", st.cache_hit_rate());
    }

    #[test]
    fn arena_admissions_never_touch_prefix_stats() {
        // prefix_len on an arena engine is inert: no hits, no misses, no
        // reuse — and cache_hit_rate stays 0 rather than NaN.
        let mut sched = Scheduler::new(MockEngine::new(1)).unwrap();
        sched
            .submit(Request { prefix_len: 2, ..req(0, 1, 4) })
            .unwrap();
        sched.run_until_idle(&mut greedy()).unwrap();
        let st = &sched.stats;
        assert_eq!(st.prefix_hits + st.prefix_misses, 0);
        assert_eq!(st.reused_tokens, 0);
        assert_eq!(st.cache_hit_rate(), 0.0);
        assert_eq!(st.computed_tokens(), st.admitted_tokens());
    }

    #[test]
    fn chunked_greedy_matches_stepwise_including_midchunk_eos() {
        // The fused-chunk contract: N=4 chunked decode must reproduce the
        // stepwise token streams bit-for-bit — including a sequence whose
        // EOS lands mid-chunk and one that exhausts its budget mid-chunk —
        // while dispatching strictly fewer decode calls.
        let run = |chunk: usize| {
            let mut sched = Scheduler::new(MockEngine::new(2).paged_mode()).unwrap();
            if chunk > 1 {
                sched.set_decode_chunk(chunk).unwrap();
            }
            let mut sampler = device_cat();
            sched.submit(req(1, 3, SG)).unwrap(); // EOS at draw 3 (mid-chunk)
            sched.submit(req(2, 5, SG)).unwrap(); // EOS at draw 5
            sched.submit(req(3, 100, 6)).unwrap(); // never EOS, budget-capped
            let mut all = sched.run_until_idle(&mut sampler).unwrap();
            all.sort_by_key(|c| c.id);
            (all, sched.stats.decode_calls)
        };
        let (stepwise, calls1) = run(1);
        let (chunked, calls4) = run(4);
        assert_eq!(stepwise.len(), 3);
        for (a, b) in stepwise.iter().zip(&chunked) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.tokens, b.tokens, "request {} token stream", a.id);
            assert_eq!(a.finish, b.finish, "request {} finish reason", a.id);
            assert_eq!(a.generated, b.generated);
        }
        assert!(
            calls4 < calls1,
            "chunked decode must dispatch fewer calls ({calls4} vs {calls1})"
        );
    }

    /// A request's prompt plus an explicit device-RNG seed; never-EOS so
    /// the whole generated stream is RNG-derived.
    fn seeded_req(id: u64, seed: u64, max_new: usize) -> Request {
        let mut prompt = vec![CONTENT; SP];
        prompt[0] = 100;
        Request { id, prompt, max_new, seed: Some(seed), prefix_len: 0 }
    }

    #[test]
    fn device_stream_survives_reordering_and_chunking() {
        // The per-request stream is keyed by (seed, draw index) alone:
        // resubmitting in a different order, onto a different slot count,
        // with a different chunk size, must reproduce every request's
        // tokens exactly.
        let run = |order: &[u64], chunk: usize, n_slots: usize| {
            let mut sched =
                Scheduler::new(MockEngine::new(n_slots).paged_mode().device_rng_mode())
                    .unwrap();
            if chunk > 1 {
                sched.set_decode_chunk(chunk).unwrap();
            }
            let mut sampler = device_cat_stochastic();
            for &id in order {
                sched.submit(seeded_req(id, 0xC0FFEE ^ id, 5)).unwrap();
            }
            let mut all = sched.run_until_idle(&mut sampler).unwrap();
            all.sort_by_key(|c| c.id);
            all.into_iter().map(|c| (c.id, c.tokens)).collect::<Vec<_>>()
        };
        let a = run(&[1, 2, 3], 1, 2);
        let b = run(&[3, 1, 2], 4, 2);
        let c = run(&[2, 3, 1], 2, 3);
        assert_eq!(a, b, "chunk 4 / reordered must match stepwise");
        assert_eq!(a, c, "chunk 2 / three slots must match stepwise");
        assert_ne!(a[0].1, a[1].1, "distinct seeds give distinct streams");
    }

    #[test]
    fn chunk_with_host_backend_bails() {
        let mut sched = Scheduler::new(MockEngine::new(2).paged_mode()).unwrap();
        sched.set_decode_chunk(2).unwrap();
        sched.submit(req(1, 2, SG)).unwrap();
        let err = format!("{:#}", sched.step(&mut greedy()).unwrap_err());
        assert!(err.contains("device-RNG"), "{err}");
    }

    #[test]
    fn set_decode_chunk_checks_capability_up_front() {
        // A non-paged engine has no chunk artifacts: the failure surfaces
        // at configuration time with the engine's own error, not as
        // per-tick Failed retirements.
        let mut sched = Scheduler::new(MockEngine::new(2)).unwrap();
        let err = format!("{:#}", sched.set_decode_chunk(4).unwrap_err());
        assert!(err.contains("paged"), "{err}");
        assert_eq!(sched.decode_chunk(), 1, "failed set leaves chunk untouched");
        sched.set_decode_chunk(1).unwrap(); // N=1 is always the stepwise path
        // And the trait default fails closed for engines that never opted in.
        struct NoChunk;
        impl SlotEngine for NoChunk {
            fn n_slots(&self) -> usize {
                1
            }
            fn prompt_len(&self) -> usize {
                SP
            }
            fn max_new_tokens(&self) -> usize {
                SG
            }
            fn prefill_slot(&mut self, _: usize, _: &Admission) -> Result<AdmitOutcome> {
                bail!("unused")
            }
            fn decode_slots(&mut self, _: &DecodeBatch) -> Result<SampleOut> {
                bail!("unused")
            }
            fn release_slot(&mut self, _: usize) -> Result<()> {
                Ok(())
            }
        }
        let mut e = NoChunk;
        e.check_decode_chunk(1).unwrap();
        let err = format!("{:#}", e.check_decode_chunk(2).unwrap_err());
        assert!(err.contains("decode_chunk"), "{err}");
        let batch = ChunkBatch {
            toks: &[0],
            pos: &[0],
            active: &[false],
            n: 2,
            quota: &[0],
            rng: DecodeRng { seeds: &[0, 0], steps: &[0], sparams: [0.0; 3] },
        };
        assert!(e.decode_slots_chunk(&batch).is_err());
    }

    #[test]
    fn chunk_waste_and_bubble_accounting() {
        // One live slot of two, chunk 4: the request retires after 2 of
        // its 4 fused steps (EOS latch), so the call's 8 slot-steps split
        // into 2 active, 2 latch-wasted (live row), and 4 dead-row bubble.
        let mut sched = Scheduler::new(MockEngine::new(2).paged_mode()).unwrap();
        sched.set_decode_chunk(4).unwrap();
        let mut sampler = device_cat();
        sched.submit(req(1, 2, SG)).unwrap(); // C C EOS
        let done = sched.run_until_idle(&mut sampler).unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].response(), &[CONTENT, CONTENT, Vocab::EOS]);
        assert_eq!(done[0].finish, FinishReason::Eos);
        let st = &sched.stats;
        assert_eq!(st.decode_calls, 1, "one fused call covers the whole tail");
        assert_eq!(st.slot_steps_total, 8, "4 fused steps x 2 slots");
        assert_eq!(st.slot_steps_active, 2, "draws 1-2 of the live row");
        assert_eq!(st.chunk_waste_tokens, 2, "latched live-row steps only");
        let util = st.utilization();
        let bubble = st.bubble_fraction();
        assert!((util + bubble - 1.0).abs() < 1e-12, "{util} + {bubble}");
    }

    /// Recorded events carrying one request's correlation id (decode
    /// dispatch spans reuse step indices as ids, so per-request checks
    /// below always filter by name too).
    fn events_for(tel: &Telemetry, id: u64) -> Vec<telemetry::Event> {
        tel.events().into_iter().filter(|e| e.id == id).collect()
    }

    #[test]
    fn telemetry_event_ordering_under_chunked_decode() {
        // The request-lifecycle event stream must stay coherent under
        // fused decode: per-track timestamps monotone, every Begin/End
        // paired, exactly one first_token per request — including the one
        // whose EOS lands mid-chunk — and the queued → admitted → prefill
        // → first-token → retired chain ordered with the right finish
        // code on the request span's End.
        let mut sched = Scheduler::new(MockEngine::new(2).paged_mode()).unwrap();
        sched.set_decode_chunk(4).unwrap();
        sched.set_telemetry(Telemetry::enabled(4096));
        let mut sampler = device_cat();
        sched.submit(req(1, 3, SG)).unwrap(); // EOS at draw 3 (mid-chunk)
        sched.submit(req(2, 5, SG)).unwrap(); // EOS at draw 5
        sched.submit(req(3, 100, 6)).unwrap(); // never EOS, budget-capped
        let done = sched.run_until_idle(&mut sampler).unwrap();
        assert_eq!(done.len(), 3);
        let evs = sched.telemetry().events();
        assert_eq!(sched.telemetry().dropped(), 0, "test buffer must not wrap");

        // Timestamps never go backwards within a track.
        let mut last: std::collections::HashMap<u32, u64> = Default::default();
        for e in &evs {
            let prev = last.entry(e.tid).or_insert(0);
            assert!(e.ts_us >= *prev, "track {} time went backwards at {:?}", e.tid, e);
            *prev = e.ts_us;
        }
        // Begin/End pairing balances on every (track, name, id) key.
        let mut open: std::collections::HashMap<(u32, &str, u64), i64> = Default::default();
        for e in &evs {
            match e.ph {
                telemetry::Ph::Begin => {
                    *open.entry((e.tid, e.name, e.id)).or_insert(0) += 1;
                }
                telemetry::Ph::End => {
                    let d = open.entry((e.tid, e.name, e.id)).or_insert(0);
                    *d -= 1;
                    assert!(*d >= 0, "End without Begin: {e:?}");
                }
                _ => {}
            }
        }
        assert!(open.values().all(|&v| v == 0), "unclosed spans: {open:?}");

        for (id, want_finish) in [
            (1u64, telemetry::FINISH_EOS),
            (2, telemetry::FINISH_EOS),
            (3, telemetry::FINISH_LENGTH),
        ] {
            let evr = events_for(sched.telemetry(), id);
            let firsts: Vec<_> = evr.iter().filter(|e| e.name == "first_token").collect();
            assert_eq!(firsts.len(), 1, "request {id}: exactly one first_token");
            let find = |name: &str, ph: telemetry::Ph| {
                evr.iter()
                    .find(|e| e.name == name && e.ph == ph)
                    .unwrap_or_else(|| panic!("request {id}: missing {name} {ph:?}"))
            };
            let q_end = find("queued", telemetry::Ph::End);
            let r_begin = find("request", telemetry::Ph::Begin);
            let r_end = find("request", telemetry::Ph::End);
            let p_end = find("prefill", telemetry::Ph::End);
            assert!(r_begin.tid >= telemetry::TID_SLOT0, "request span lives on a slot track");
            assert_eq!(r_end.arg, want_finish, "request {id} finish code");
            assert!(q_end.ts_us <= r_begin.ts_us, "admission after queue close");
            assert!(r_begin.ts_us <= p_end.ts_us, "prefill inside the request span");
            assert!(p_end.ts_us <= firsts[0].ts_us, "first token after prefill");
            assert!(firsts[0].ts_us <= r_end.ts_us, "retirement after first token");
        }
        // Every generated token hit exactly one latency histogram: the
        // first of each request lands in TTFT, the rest in inter-token
        // (fused chunks record the amortized gap per covered token).
        let tel = sched.telemetry();
        assert_eq!(tel.hist(Hist::Ttft).count(), 3);
        assert_eq!(tel.hist(Hist::QueueWait).count(), 3);
        let gen_total: u64 = done.iter().map(|c| c.generated as u64).sum();
        assert_eq!(tel.hist(Hist::InterToken).count() + 3, gen_total);
    }

    /// A mock whose first `faults` prefill calls error before touching
    /// the inner engine — the transient-fault shape `ChaosEngine`
    /// injects. The scheduler's best-effort release after a faulted
    /// prefill lands on a still-free slot, so it is absorbed here.
    struct FaultFirstPrefills {
        inner: MockEngine,
        faults: usize,
    }

    impl SlotEngine for FaultFirstPrefills {
        fn n_slots(&self) -> usize {
            self.inner.n_slots()
        }
        fn prompt_len(&self) -> usize {
            self.inner.prompt_len()
        }
        fn max_new_tokens(&self) -> usize {
            self.inner.max_new_tokens()
        }
        fn prefill_slot(&mut self, slot: usize, adm: &Admission) -> Result<AdmitOutcome> {
            if self.faults > 0 {
                self.faults -= 1;
                bail!("transient prefill fault");
            }
            self.inner.prefill_slot(slot, adm)
        }
        fn decode_slots(&mut self, batch: &DecodeBatch) -> Result<SampleOut> {
            self.inner.decode_slots(batch)
        }
        fn release_slot(&mut self, slot: usize) -> Result<()> {
            if self.inner.plans[slot].is_some() {
                self.inner.release_slot(slot)
            } else {
                Ok(())
            }
        }
    }

    #[test]
    fn telemetry_requeue_reopens_the_queued_span() {
        // A transient prefill fault must leave a legible trace: the
        // aborted request span closes with FINISH_ABORTED, requeue and
        // prefill_fault instants fire, and a fresh queued span covers the
        // backoff window — then the retry admits and the request
        // completes with a normal EOS chain.
        let engine = FaultFirstPrefills { inner: MockEngine::new(1), faults: 1 };
        let policy = FaultPolicy {
            max_retries: 3,
            backoff_steps: 1,
            deadline_steps: 0,
            quarantine_after: 0,
        };
        let mut sched = Scheduler::with_policy(engine, policy).unwrap();
        sched.set_telemetry(Telemetry::enabled(1024));
        sched.submit(req(9, 2, SG)).unwrap();
        let done = sched.run_until_idle(&mut greedy()).unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].finish, FinishReason::Eos);
        assert_eq!(sched.stats.requeues, 1);

        let evs = events_for(sched.telemetry(), 9);
        let count = |name: &str, ph: telemetry::Ph| {
            evs.iter().filter(|e| e.name == name && e.ph == ph).count()
        };
        assert_eq!(count("queued", telemetry::Ph::Begin), 2, "requeue re-opens the queued span");
        assert_eq!(count("queued", telemetry::Ph::End), 2);
        assert_eq!(count("requeue", telemetry::Ph::Instant), 1);
        assert_eq!(count("prefill_fault", telemetry::Ph::Instant), 1);
        assert_eq!(count("first_token", telemetry::Ph::Instant), 1);
        let ends: Vec<i64> = evs
            .iter()
            .filter(|e| e.name == "request" && e.ph == telemetry::Ph::End)
            .map(|e| e.arg)
            .collect();
        assert_eq!(ends, vec![telemetry::FINISH_ABORTED, telemetry::FINISH_EOS]);
        // Queue-wait records per admission attempt, both anchored at the
        // original submit time.
        assert_eq!(sched.telemetry().hist(Hist::QueueWait).count(), 2);
    }

    #[test]
    fn zero_quota_chunk_rows_consume_nothing() {
        // Regression (chunk walk, zero-quota row): the old walk returned 1
        // for quota == 0, consuming one frozen filler token and feeding it
        // into `Seq::pending`.
        // [n=4, b=2] row-major ids: slot 0 is frozen EOS filler, slot 1
        // emits content then EOS at step 2.
        let ids = vec![
            Vocab::EOS, CONTENT, // step 0
            Vocab::EOS, CONTENT, // step 1
            Vocab::EOS, Vocab::EOS, // step 2
            Vocab::EOS, CONTENT, // step 3 (filler past slot 1's latch)
        ];
        assert_eq!(chunk_consumed(&ids, 2, 0, 4, 0), 0, "zero quota consumes nothing");
        assert_eq!(chunk_consumed(&ids, 2, 1, 4, 0), 0);
        // quota >= 1 semantics unchanged: EOS-immediately consumes 1, the
        // EOS-terminated row consumes through its EOS, quota caps the walk.
        assert_eq!(chunk_consumed(&ids, 2, 0, 4, 3), 1);
        assert_eq!(chunk_consumed(&ids, 2, 1, 4, 8), 3);
        assert_eq!(chunk_consumed(&ids, 2, 1, 4, 2), 2);
        assert_eq!(chunk_consumed(&ids, 2, 1, 4, 1), 1);
    }

    #[test]
    fn preempted_slot_requeues_and_replays_to_completion() {
        // Mid-decode pool exhaustion: slot 0's first reservation is
        // refused, so its request must release its pages, requeue with
        // backoff, re-admit, and replay FROM SCRATCH to the same bytes —
        // while the co-scheduled request never notices.
        let eng = MockEngine::new(2).paged_mode().deny_reserves(0, 1);
        let mut sched = Scheduler::new(eng).unwrap();
        sched.set_telemetry(Telemetry::enabled(1024));
        let mut sampler = greedy();
        sched.submit(req(1, 3, SG)).unwrap();
        sched.submit(req(2, 2, SG)).unwrap();
        let all = sched.run_until_idle(&mut sampler).unwrap();
        assert_eq!(all.len(), 2);
        let c1 = all.iter().find(|c| c.id == 1).unwrap();
        assert_eq!(c1.finish, FinishReason::Eos);
        assert_eq!(c1.response(), &[CONTENT, CONTENT, CONTENT, Vocab::EOS]);
        let c2 = all.iter().find(|c| c.id == 2).unwrap();
        assert_eq!(c2.finish, FinishReason::Eos);
        assert_eq!(c2.response(), &[CONTENT, CONTENT, Vocab::EOS]);
        assert_eq!(sched.stats.preemptions, 1);
        assert_eq!(sched.stats.requeues, 1);
        assert_eq!(sched.stats.retired_preempted, 0);
        assert_eq!(sched.stats.prefills, 3, "the preempted request prefilled twice");
        // The trace mirrors the prefill-fault shape: aborted span, a
        // `preempt` instant (not `prefill_fault`), re-opened queued span,
        // then the replay's normal EOS chain.
        let evs = events_for(sched.telemetry(), 1);
        let count = |name: &str, ph: telemetry::Ph| {
            evs.iter().filter(|e| e.name == name && e.ph == ph).count()
        };
        assert_eq!(count("preempt", telemetry::Ph::Instant), 1);
        assert_eq!(count("prefill_fault", telemetry::Ph::Instant), 0);
        assert_eq!(count("queued", telemetry::Ph::Begin), 2);
        let ends: Vec<i64> = evs
            .iter()
            .filter(|e| e.name == "request" && e.ph == telemetry::Ph::End)
            .map(|e| e.arg)
            .collect();
        assert_eq!(ends, vec![telemetry::FINISH_ABORTED, telemetry::FINISH_EOS]);
    }

    #[test]
    fn preemption_past_retry_budget_retires_preempted() {
        // A slot that can NEVER draw its next page burns the shared retry
        // budget (max_retries = 2 ⇒ 3 preemptions) and retires as
        // Preempted with the tokens it had, instead of looping forever or
        // aborting the batch.
        let eng = MockEngine::new(1).paged_mode().deny_reserves(0, u32::MAX);
        let mut sched = Scheduler::new(eng).unwrap();
        let mut sampler = greedy();
        sched.submit(req(7, SG as i32 + 2, SG)).unwrap(); // never EOS
        let all = sched.run_until_idle(&mut sampler).unwrap();
        assert_eq!(all.len(), 1);
        assert_eq!(all[0].finish, FinishReason::Preempted { preemptions: 3 });
        // Each attempt sampled exactly one token (the prefill's pending
        // row) before losing its pages at the reservation gate.
        assert_eq!(all[0].generated, 1);
        assert_eq!(sched.stats.preemptions, 3);
        assert_eq!(sched.stats.requeues, 2);
        assert_eq!(sched.stats.retired_preempted, 1);
        assert_eq!(sched.stats.completed, 1);
        assert_eq!(sched.stats.retired_failed, 0, "preemption is not a fault");
    }

    #[test]
    fn kv_pressure_defers_admissions_until_slots_free() {
        // can_admit refuses while a slot is live: the second request waits
        // IN the queue (no prefill fault, no requeue) and admits only
        // after the first retires and frees its pages.
        let eng = MockEngine::new(2).paged_mode().admit_cap(1);
        let mut sched = Scheduler::new(eng).unwrap();
        let mut sampler = greedy();
        sched.submit(req(1, 2, SG)).unwrap();
        sched.submit(req(2, 2, SG)).unwrap();
        let all = sched.run_until_idle(&mut sampler).unwrap();
        assert_eq!(all.len(), 2);
        assert!(all.iter().all(|c| c.finish == FinishReason::Eos), "{all:?}");
        assert!(sched.stats.admission_deferrals > 0);
        assert_eq!(sched.stats.prefill_faults, 0, "deferral must not burn retries");
        assert_eq!(sched.stats.requeues, 0);
        assert!(
            sched
                .engine
                .decode_active
                .iter()
                .all(|m| m.iter().filter(|a| **a).count() <= 1),
            "the capacity gate admitted a second live sequence"
        );

        // An undersized pool on an EMPTY engine admits anyway — the
        // prefill fails loudly (or, here, succeeds) instead of the queue
        // deadlocking behind a capacity that will never appear.
        let eng = MockEngine::new(1).paged_mode().admit_cap(0);
        let mut sched = Scheduler::new(eng).unwrap();
        sched.submit(req(3, 1, SG)).unwrap();
        let all = sched.run_until_idle(&mut sampler).unwrap();
        assert_eq!(all.len(), 1);
        assert_eq!(all[0].finish, FinishReason::Eos);
    }

    #[test]
    fn chunked_preemption_replays_bit_identically() {
        // The acceptance bit-match, chunk flavor: a preempted request's
        // final bytes equal its never-preempted run, because the device
        // stream is a pure function of (request seed, draw index) and the
        // requeue recomputes from scratch.
        let run = |deny: u32| -> Vec<Completion> {
            let eng = MockEngine::new(2)
                .paged_mode()
                .device_rng_mode()
                .deny_reserves(0, deny);
            let mut sched = Scheduler::new(eng).unwrap();
            sched.set_decode_chunk(4).unwrap();
            let mut sampler = device_cat_stochastic();
            sched.submit(req(1, SG as i32 + 2, 6)).unwrap();
            sched.submit(req(2, SG as i32 + 2, 6)).unwrap();
            let mut all = sched.run_until_idle(&mut sampler).unwrap();
            all.sort_by_key(|c| c.id);
            all
        };
        let clean = run(0);
        let preempted = run(1);
        assert_eq!(clean.len(), 2);
        assert_eq!(preempted.len(), 2);
        for (a, b) in clean.iter().zip(&preempted) {
            assert_eq!(a.id, b.id);
            assert_eq!(
                a.tokens, b.tokens,
                "request {} bytes diverged across preemption",
                a.id
            );
            assert_eq!(a.finish, b.finish);
        }
    }
}
