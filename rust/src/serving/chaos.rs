//! Deterministic fault injection for the serving scheduler.
//!
//! [`ChaosEngine`] wraps any [`SlotEngine`] and injects faults *before*
//! delegating to the inner engine, so an injected transient fault leaves
//! the inner engine's per-slot state (KV rows, cursors) exactly as it was
//! — the scheduler's retry then replays the call against pristine state
//! and recovery is bit-identical to the fault-free run (the chaos golden
//! in `rust/tests/failure_injection.rs`).
//!
//! Faults come in three flavors, all seeded through [`Rng`] (probabilistic)
//! or scheduled by call count (exact, for counter assertions):
//!
//! * **transient prefill/decode faults** — the call errors once; a retry
//!   (decode) or a backed-off re-admission (prefill) succeeds;
//! * **permanently broken slots** — every prefill into the slot faults,
//!   driving the scheduler's quarantine path;
//! * **slow ticks** — a decode call sleeps before running, stretching tail
//!   latency without failing (the bench's goodput-under-jitter knob).
//!
//! The wrapper also keeps a forgiving view of which slots the *inner*
//! engine actually admitted: a best-effort `release_slot` after an
//! injected admission fault is absorbed here (erroring like the hybrid
//! engine's KV ledger does for a free slot) instead of reaching an inner
//! engine that never saw the prefill.

use std::time::Duration;

use anyhow::{bail, Result};

use crate::sampling::SampleOut;
use crate::serving::{Admission, AdmitOutcome, ChunkBatch, DecodeBatch, SlotEngine};
use crate::util::rng::Rng;

/// Fault schedule for a [`ChaosEngine`]. Defaults inject nothing.
#[derive(Debug, Clone)]
pub struct ChaosConfig {
    /// Seed for the probabilistic fault draws.
    pub seed: u64,
    /// Probability any one prefill call faults transiently.
    pub prefill_fault_p: f64,
    /// Probability any one decode call faults transiently.
    pub decode_fault_p: f64,
    /// Deterministic schedule: fault every k-th prefill call (0 = off).
    pub fault_every_prefill: u64,
    /// Deterministic schedule: fault every k-th decode call (0 = off).
    pub fault_every_decode: u64,
    /// Slots whose every prefill faults (permanent slot faults — the
    /// scheduler's quarantine driver).
    pub broken_slots: Vec<usize>,
    /// Probability a decode call is delayed by `slow_tick` before running.
    pub slow_tick_p: f64,
    /// Injected delay for slow ticks.
    pub slow_tick: Duration,
}

impl Default for ChaosConfig {
    fn default() -> Self {
        ChaosConfig {
            seed: 0,
            prefill_fault_p: 0.0,
            decode_fault_p: 0.0,
            fault_every_prefill: 0,
            fault_every_decode: 0,
            broken_slots: Vec::new(),
            slow_tick_p: 0.0,
            slow_tick: Duration::from_millis(1),
        }
    }
}

/// What the wrapper injected — the ground truth the scheduler's
/// `SchedStats` fault counters are asserted against.
#[derive(Debug, Default, Clone)]
pub struct ChaosStats {
    /// Prefill calls intercepted (faulted + passed through).
    pub prefill_calls: u64,
    /// Decode calls intercepted.
    pub decode_calls: u64,
    /// Injected prefill faults (transient + broken-slot).
    pub prefill_faults: u64,
    /// Injected decode faults.
    pub decode_faults: u64,
    /// Injected slow ticks.
    pub slow_ticks: u64,
}

/// A [`SlotEngine`] that fails on purpose. See the module docs.
pub struct ChaosEngine<E: SlotEngine> {
    pub inner: E,
    pub cfg: ChaosConfig,
    /// Everything injected so far.
    pub injected: ChaosStats,
    /// Which slots the INNER engine currently holds an admission for
    /// (injected prefill faults never reach it, so the scheduler's
    /// best-effort release after one must be absorbed here).
    live: Vec<bool>,
    rng: Rng,
}

impl<E: SlotEngine> ChaosEngine<E> {
    pub fn new(inner: E, cfg: ChaosConfig) -> Self {
        let n = inner.n_slots();
        let rng = Rng::new(cfg.seed);
        ChaosEngine { inner, cfg, injected: ChaosStats::default(), live: vec![false; n], rng }
    }

    /// Unwrap, handing the inner engine back.
    pub fn into_inner(self) -> E {
        self.inner
    }

    /// One draw per intercepted call keeps the injection schedule a pure
    /// function of (seed, call index); `p == 0` draws nothing so disabled
    /// channels do not perturb the stream of enabled ones.
    fn roll(&mut self, p: f64) -> bool {
        p > 0.0 && self.rng.chance(p)
    }
}

impl<E: SlotEngine> SlotEngine for ChaosEngine<E> {
    fn n_slots(&self) -> usize {
        self.inner.n_slots()
    }

    fn prompt_len(&self) -> usize {
        self.inner.prompt_len()
    }

    fn max_new_tokens(&self) -> usize {
        self.inner.max_new_tokens()
    }

    fn supports_padded_prompts(&self) -> bool {
        self.inner.supports_padded_prompts()
    }

    fn paged(&self) -> bool {
        self.inner.paged()
    }

    fn begin_serving(&mut self) -> Result<()> {
        for l in self.live.iter_mut() {
            *l = false;
        }
        self.inner.begin_serving()
    }

    fn prefill_slot(&mut self, slot: usize, adm: &Admission) -> Result<AdmitOutcome> {
        self.injected.prefill_calls += 1;
        if self.cfg.broken_slots.contains(&slot) {
            self.injected.prefill_faults += 1;
            bail!("chaos: permanent fault on slot {slot} (prefill {})", self.injected.prefill_calls);
        }
        let scheduled = self.cfg.fault_every_prefill > 0
            && self.injected.prefill_calls % self.cfg.fault_every_prefill == 0;
        if scheduled || self.roll(self.cfg.prefill_fault_p) {
            self.injected.prefill_faults += 1;
            bail!("chaos: transient prefill fault (call {})", self.injected.prefill_calls);
        }
        let out = self.inner.prefill_slot(slot, adm)?;
        self.live[slot] = true;
        Ok(out)
    }

    fn decode_slots(&mut self, batch: &DecodeBatch) -> Result<SampleOut> {
        self.injected.decode_calls += 1;
        if self.roll(self.cfg.slow_tick_p) {
            self.injected.slow_ticks += 1;
            std::thread::sleep(self.cfg.slow_tick);
        }
        let scheduled = self.cfg.fault_every_decode > 0
            && self.injected.decode_calls % self.cfg.fault_every_decode == 0;
        if scheduled || self.roll(self.cfg.decode_fault_p) {
            self.injected.decode_faults += 1;
            bail!("chaos: transient decode fault (call {})", self.injected.decode_calls);
        }
        self.inner.decode_slots(batch)
    }

    fn check_decode_chunk(&self, n: usize) -> Result<()> {
        self.inner.check_decode_chunk(n)
    }

    fn decode_slots_chunk(&mut self, batch: &ChunkBatch) -> Result<Vec<i32>> {
        // Same injection schedule as the stepwise path: a fused chunk is
        // one decode dispatch, so it rolls one fault and one slow tick.
        self.injected.decode_calls += 1;
        if self.roll(self.cfg.slow_tick_p) {
            self.injected.slow_ticks += 1;
            std::thread::sleep(self.cfg.slow_tick);
        }
        let scheduled = self.cfg.fault_every_decode > 0
            && self.injected.decode_calls % self.cfg.fault_every_decode == 0;
        if scheduled || self.roll(self.cfg.decode_fault_p) {
            self.injected.decode_faults += 1;
            bail!("chaos: transient decode fault (call {})", self.injected.decode_calls);
        }
        self.inner.decode_slots_chunk(batch)
    }

    fn can_admit(&self, prompt: &[i32], prefix_len: usize) -> bool {
        self.inner.can_admit(prompt, prefix_len)
    }

    fn reserve_decode(&mut self, slot: usize, n: usize) -> Result<bool> {
        // Pass-through, not an injection channel: preemption is the
        // LEDGER's capacity signal, not a fault — chaos perturbs the
        // engine calls around it and the requeue path gets exercised by
        // whatever pressure the inner pool is really under.
        self.inner.reserve_decode(slot, n)
    }

    fn release_slot(&mut self, slot: usize) -> Result<()> {
        if !self.live[slot] {
            // The scheduler's best-effort release after an injected
            // admission fault: the inner engine never admitted, so there
            // is nothing to free (mirrors the KV ledger's already-free
            // error).
            bail!("chaos: slot {slot} holds no inner admission");
        }
        self.inner.release_slot(slot)?;
        self.live[slot] = false;
        Ok(())
    }

    fn note_generated(&mut self, n: u64) {
        self.inner.note_generated(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampling::{PendingRow, TrafficClass};

    /// Minimal inner engine: counts calls, never fails itself.
    struct Flat {
        n: usize,
        prefills: u64,
        decodes: u64,
        releases: u64,
    }

    impl SlotEngine for Flat {
        fn n_slots(&self) -> usize {
            self.n
        }

        fn prompt_len(&self) -> usize {
            4
        }

        fn max_new_tokens(&self) -> usize {
            8
        }

        fn prefill_slot(&mut self, _slot: usize, _adm: &Admission) -> Result<AdmitOutcome> {
            self.prefills += 1;
            Ok(AdmitOutcome::cold(PendingRow::Id(1)))
        }

        fn decode_slots(&mut self, batch: &DecodeBatch) -> Result<SampleOut> {
            self.decodes += 1;
            Ok(SampleOut::Ids(vec![1; batch.toks.len()]))
        }

        fn release_slot(&mut self, _slot: usize) -> Result<()> {
            self.releases += 1;
            Ok(())
        }
    }

    fn flat(n: usize) -> Flat {
        Flat { n, prefills: 0, decodes: 0, releases: 0 }
    }

    #[test]
    fn periodic_schedule_is_exact_and_skips_inner() {
        let mut e = ChaosEngine::new(
            flat(2),
            ChaosConfig { fault_every_decode: 3, ..Default::default() },
        );
        let batch = DecodeBatch {
            toks: &[1, 1],
            pos: &[0, 0],
            starts: &[0, 0],
            active: &[true, true],
            traffic: TrafficClass::DeviceIds,
            rng: None,
        };
        let mut faults = 0;
        for _ in 0..9 {
            if e.decode_slots(&batch).is_err() {
                faults += 1;
            }
        }
        assert_eq!(faults, 3, "every 3rd call faults");
        assert_eq!(e.injected.decode_faults, 3);
        // Faulted calls never reached the inner engine.
        assert_eq!(e.inner.decodes, 6);
    }

    #[test]
    fn broken_slot_always_faults_and_release_is_absorbed() {
        let mut e = ChaosEngine::new(
            flat(2),
            ChaosConfig { broken_slots: vec![0], ..Default::default() },
        );
        let adm = Admission {
            prompt: &[1; 4],
            prefix_len: 0,
            traffic: TrafficClass::DeviceIds,
            rng: None,
        };
        for _ in 0..3 {
            assert!(e.prefill_slot(0, &adm).is_err());
        }
        assert!(e.prefill_slot(1, &adm).is_ok());
        assert_eq!(e.injected.prefill_faults, 3);
        assert_eq!(e.inner.prefills, 1, "broken-slot calls never reach inner");
        // Best-effort release of the never-admitted slot stays here.
        assert!(e.release_slot(0).is_err());
        assert_eq!(e.inner.releases, 0);
        // Releasing the real admission goes through.
        assert!(e.release_slot(1).is_ok());
        assert_eq!(e.inner.releases, 1);
    }

    #[test]
    fn probabilistic_schedule_is_seed_deterministic() {
        let run = |seed: u64| {
            let mut e = ChaosEngine::new(
                flat(1),
                ChaosConfig { seed, decode_fault_p: 0.3, ..Default::default() },
            );
            let batch = DecodeBatch {
                toks: &[1],
                pos: &[0],
                starts: &[0],
                active: &[true],
                traffic: TrafficClass::DeviceIds,
                rng: None,
            };
            (0..32).map(|_| e.decode_slots(&batch).is_err()).collect::<Vec<_>>()
        };
        assert_eq!(run(7), run(7), "same seed, same schedule");
        assert_ne!(run(7), run(8), "different seed, different schedule");
    }
}
