//! Integration: the continuous-batching serving path over real AOT
//! artifacts (requires `make artifacts` with the `prefill_slot` /
//! `decode_slots` entries). Each test passes vacuously when artifacts are
//! missing or predate the serving entry points (the mixed-length goldens
//! additionally require the `padded_prompts` capability), so tier-1 stays
//! green on a bare checkout; the scheduler's policy logic is covered
//! without artifacts by the unit tests in `rust/src/serving/mod.rs`.

use std::rc::Rc;

use dschat::data::synthetic::{TaskGen, Vocab};
use dschat::hybrid::HybridEngine;
use dschat::runtime::{Engine, Manifest};
use dschat::sampling::{DeviceTopK, HostFullRow, RowRef, SamplerConfig, SamplingBackend};
use dschat::serving::{Completion, Request, Scheduler};
use dschat::util::rng::Rng;

const DIR: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/tiny");

fn serving_artifacts() -> bool {
    Manifest::load(DIR).map(|m| m.has_serving()).unwrap_or(false)
}

fn padded_artifacts() -> bool {
    Manifest::load(DIR)
        .map(|m| m.has_serving() && m.padded_prompts)
        .unwrap_or(false)
}

fn paged_artifacts() -> bool {
    Manifest::load(DIR)
        .map(|m| m.has_serving() && m.has_paged_serving())
        .unwrap_or(false)
}

fn sampled_artifacts() -> bool {
    match Manifest::load(DIR) {
        Ok(m) => {
            m.artifacts.contains_key("prefill_slot_sampled")
                && m.artifacts.contains_key("decode_slots_sampled")
                && m.sample_k > 0
        }
        Err(_) => false,
    }
}

fn golden_sampler() -> HostFullRow {
    HostFullRow::new(
        SamplerConfig {
            temperature: 0.9,
            top_k: 8,
            top_p: 0.95,
            repetition_penalty: 1.1,
            ..Default::default()
        },
        7,
    )
}

/// Build a scheduler (arena or block-paged serving cache), submit `b + 2`
/// requests with a staggered pattern (two up front, the rest after one
/// step), run to idle, and return the scheduler plus completions sorted by
/// id and the prompts used.
fn run_staggered_on(
    backend: &mut dyn SamplingBackend,
    paged: bool,
) -> (Scheduler<HybridEngine>, Vec<Completion>, Vec<Vec<i32>>) {
    let engine = Rc::new(Engine::cpu().unwrap());
    let mut he = HybridEngine::init(engine, DIR, 0, false).unwrap();
    he.use_paged_serving(paged).unwrap();
    let m = he.manifest();
    let (b, sp, sg) = (m.batch, m.prompt_len, m.gen_len);
    let task = TaskGen::new(m.actor.vocab, sp, sg);
    let mut rng = Rng::new(41);
    let prompts: Vec<Vec<i32>> =
        (0..b + 2).map(|_| task.sample_prompt(&mut rng).tokens).collect();

    let mut sched = Scheduler::new(he).unwrap();
    let mut done = Vec::new();
    for (id, p) in prompts.iter().enumerate().take(2) {
        sched
            .submit(Request {
                id: id as u64,
                prompt: p.clone(),
                max_new: sg,
                seed: None,
                prefix_len: 0,
            })
            .unwrap();
    }
    done.extend(sched.step(backend).unwrap());
    for (id, p) in prompts.iter().enumerate().skip(2) {
        sched
            .submit(Request {
                id: id as u64,
                prompt: p.clone(),
                max_new: sg,
                seed: None,
                prefix_len: 0,
            })
            .unwrap();
    }
    done.extend(sched.run_until_idle(backend).unwrap());
    done.sort_by_key(|c| c.id);
    (sched, done, prompts)
}

fn run_staggered_with(
    backend: &mut dyn SamplingBackend,
) -> (Scheduler<HybridEngine>, Vec<Completion>, Vec<Vec<i32>>) {
    run_staggered_on(backend, false)
}

fn run_staggered() -> (Scheduler<HybridEngine>, Vec<Completion>, Vec<Vec<i32>>) {
    run_staggered_with(&mut golden_sampler())
}

#[test]
fn staggered_serving_completes_all_and_preserves_prompts() {
    if !serving_artifacts() {
        eprintln!("skipping: {DIR} missing serving artifacts (run `make artifacts`)");
        return;
    }
    let (sched, done, prompts) = run_staggered();
    let b = sched.engine.manifest().batch;
    let sg = sched.engine.manifest().gen_len;
    assert_eq!(done.len(), b + 2, "every request completes");
    for (id, c) in done.iter().enumerate() {
        assert_eq!(c.id, id as u64);
        // Prompt region copied verbatim into the sequence.
        assert_eq!(&c.tokens[..c.prompt_len], prompts[id].as_slice(), "req {id}");
        assert!(c.generated >= 1 && c.generated <= sg, "req {id}: {}", c.generated);
        assert_eq!(c.tokens.len(), c.prompt_len + c.generated);
    }
    // More requests than slots forces queueing and slot reuse.
    assert_eq!(sched.stats.admitted as usize, b + 2);
    assert_eq!(sched.stats.prefills as usize, b + 2);
    assert!(sched.stats.peak_queue_depth >= 2, "{}", sched.stats.peak_queue_depth);
    assert!(done.iter().any(|c| c.queued_steps > 0), "someone must have waited");
    assert!(sched.is_idle());
    // The engine counted the serving tokens in its generation ledger.
    let total: usize = done.iter().map(|c| c.generated).sum();
    assert_eq!(sched.engine.stats.gen_tokens as usize, total);
}

#[test]
fn serving_path_is_bit_deterministic() {
    // The continuous-batching analogue of the PR 1 generate golden: the
    // same request trace through a fresh engine must reproduce the exact
    // token sequences (device-resident per-slot decode can't perturb
    // sampling inputs).
    if !serving_artifacts() {
        eprintln!("skipping: {DIR} missing serving artifacts (run `make artifacts`)");
        return;
    }
    let (_, first, _) = run_staggered();
    let (_, again, _) = run_staggered();
    assert_eq!(first.len(), again.len());
    for (a, b) in first.iter().zip(&again) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.tokens, b.tokens, "req {}", a.id);
        assert_eq!(a.finish, b.finish);
    }
}

#[test]
fn serving_cache_accounting_survives_generate_reentry() {
    // The serving cache participates in the same alloc/free ledger as the
    // batch path: generate() after a serving session replaces the cache
    // without double-counting.
    if !serving_artifacts() {
        eprintln!("skipping: {DIR} missing serving artifacts (run `make artifacts`)");
        return;
    }
    let (sched, _, _) = run_staggered();
    let mut he = sched.engine;
    let kv_live = he.memory.live_named("kv_cache");
    assert!(kv_live > 0, "serving cache must be tracked");
    let m = he.manifest();
    let (b, sp, sg) = (m.batch, m.prompt_len, m.gen_len);
    let task = TaskGen::new(m.actor.vocab, sp, sg);
    let mut rng = Rng::new(5);
    let mut flat = Vec::with_capacity(b * sp);
    for _ in 0..b {
        flat.extend_from_slice(&task.sample_prompt(&mut rng).tokens);
    }
    let mut sampler = HostFullRow::new(SamplerConfig { greedy: true, ..Default::default() }, 0);
    he.generate(&flat, &mut sampler).unwrap();
    assert_eq!(he.memory.live_named("kv_cache"), kv_live, "re-entry double-counted kv");
}

#[test]
fn device_greedy_serving_matches_host_greedy_under_staggered_admission() {
    // The serving-side device-sampling golden: the same staggered request
    // trace through the `_sampled` artifacts (per-tick fetch = [b] ids)
    // must retire exactly the sequences the host full-row greedy path
    // retires — slot assignment, finish reasons, and every token.
    if !serving_artifacts() || !sampled_artifacts() {
        eprintln!("skipping: {DIR} missing device-sampling artifacts (run `make artifacts`)");
        return;
    }
    let greedy = SamplerConfig { greedy: true, ..Default::default() };
    let (_, host, _) = run_staggered_with(&mut HostFullRow::new(greedy.clone(), 0));
    let m = Manifest::load(DIR).unwrap();
    let mut device = DeviceTopK::new(greedy, 0, m.sample_k, m.actor.vocab).unwrap();
    let (sched, dev, _) = run_staggered_with(&mut device);
    assert_eq!(host.len(), dev.len());
    for (h, d) in host.iter().zip(&dev) {
        assert_eq!(h.id, d.id);
        assert_eq!(h.tokens, d.tokens, "req {}", h.id);
        assert_eq!(h.finish, d.finish);
        assert_eq!(h.slot, d.slot);
    }
    // The device path's decode fetches are O(b) ids — spot-check the byte
    // ledger: decode_slots_sampled fetched 4 bytes per slot per call.
    // (Only meaningful on the zero-copy path; a wrapper that forces the
    // fused-tuple fallback fetches whole tuples and is counted separately.)
    let stats = sched.engine.engine.stats();
    let st = stats.get("decode_slots_sampled").expect("device decode artifact was exercised");
    if st.fallback_untuples == 0 {
        assert_eq!(
            st.bytes_fetched,
            4 * sched.engine.manifest().batch as u64 * st.calls,
            "device-greedy decode must fetch [b] i32 ids per call, nothing more"
        );
    }
}

#[test]
fn donated_decode_keeps_cache_accounting_and_reuse_honest() {
    // KV buffer donation: decode artifacts are compiled with donate_argnums
    // on the K/V inputs, so XLA may update the cache in place. The engine's
    // contract is that the occupancy ledger, the memory tracker, and slot
    // reuse stay correct across donated steps — a stale (donated) handle
    // surviving anywhere would break one of these immediately.
    if !serving_artifacts() {
        eprintln!("skipping: {DIR} missing serving artifacts (run `make artifacts`)");
        return;
    }
    // The manifest must record the donation (artifact built by this PR's
    // aot.py); older artifact sets pass vacuously.
    let m = Manifest::load(DIR).unwrap();
    if m.artifact("decode_slots").unwrap().donates.is_empty() {
        eprintln!("skipping: artifacts predate KV donation (run `make artifacts`)");
        return;
    }
    let n_params = m.actor_params.len();
    assert_eq!(
        m.artifact("decode_slots").unwrap().donates,
        vec![n_params, n_params + 1],
        "donated positions must be exactly the K/V cache inputs"
    );

    let engine = Rc::new(Engine::cpu().unwrap());
    let he = HybridEngine::init(engine, DIR, 0, false).unwrap();
    let man = he.manifest();
    let (b, sp, sg) = (man.batch, man.prompt_len, man.gen_len);
    let task = TaskGen::new(man.actor.vocab, sp, sg);
    let mut rng = Rng::new(17);

    // A full serving cycle on slot 0 with every decode donating its cache.
    let mut sched = Scheduler::new(he).unwrap();
    let mut sampler = golden_sampler();
    let kv_live = sched.engine.memory.live_named("kv_cache");
    assert!(kv_live > 0);
    let p0 = task.sample_prompt(&mut rng).tokens;
    sched
        .submit(Request { id: 0, prompt: p0, max_new: sg, seed: None, prefix_len: 0 })
        .unwrap();
    let done = sched.run_until_idle(&mut sampler).unwrap();
    assert_eq!(done.len(), 1);
    assert!(done[0].generated >= 1);
    // In-place updates must not disturb the byte ledger: the live cache is
    // the same allocation size, and the slot is reusable immediately.
    assert_eq!(sched.engine.memory.live_named("kv_cache"), kv_live);
    assert_eq!(sched.engine.free_slots(), b);
    let p1 = task.sample_prompt(&mut rng).tokens;
    sched
        .submit(Request { id: 1, prompt: p1, max_new: sg, seed: None, prefix_len: 0 })
        .unwrap();
    let done = sched.run_until_idle(&mut sampler).unwrap();
    assert_eq!(done.len(), 1, "slot reuse after donated decode steps");
    assert_eq!(done[0].slot, 0);

    // Batch path: generate() drives donated decode_step calls; occupancy
    // (advance_all) and the tracker must stay balanced across re-entry.
    let mut he = sched.engine;
    let mut flat = Vec::with_capacity(b * sp);
    for _ in 0..b {
        flat.extend_from_slice(&task.sample_prompt(&mut rng).tokens);
    }
    let mut greedy = HostFullRow::new(SamplerConfig { greedy: true, ..Default::default() }, 0);
    let first = he.generate(&flat, &mut greedy).unwrap();
    assert_eq!(he.memory.live_named("kv_cache"), kv_live, "generate re-entry double-count");
    let again = he.generate(&flat, &mut HostFullRow::new(
        SamplerConfig { greedy: true, ..Default::default() }, 0)).unwrap();
    assert_eq!(first, again, "donated in-place updates must not perturb results");
}

// ---------------------------------------------------------------------------
// Mixed-length goldens: variable-length prompts through the left-padded
// admission path must be BIT-EXACT with the same prompt run at exact
// length. Two independent references pin this:
//   * `generate_mixed` — the fixed-batch padded path (batch prefill +
//     lockstep decode_slots), the issue's "fixed-batch generate";
//   * `naive_exact_generate` — the no-cache full forward over the TRUE
//     (unpadded) token prefix, positions 0..len with no padding anywhere
//     in the math: the ground-truth exact-length computation.
// ---------------------------------------------------------------------------

/// Exact-length reference: generate from `prompt` by re-running the
/// full-sequence forward (`logits_forward`) each step and reading row 0's
/// logits at the true last position. `stream` = per-request RNG stream
/// (the scheduler's seeded-request discipline); `None` uses the backend's
/// global stream.
fn naive_exact_generate(
    he: &mut HybridEngine,
    prompt: &[i32],
    max_new: usize,
    backend: &mut dyn SamplingBackend,
    mut stream: Option<&mut Rng>,
) -> Vec<i32> {
    let m = he.manifest();
    let (b, s, vocab) = (m.batch, m.seq_len, m.actor.vocab);
    let mut seq = prompt.to_vec();
    for _ in 0..max_new {
        let mut batch = vec![Vocab::PAD; b * s];
        for r in 0..b {
            batch[r * s..r * s + seq.len()].copy_from_slice(&seq);
        }
        let logits = he.full_logits(&batch).unwrap();
        let base = (seq.len() - 1) * vocab;
        let row = RowRef::Logits(&logits[base..base + vocab]);
        let t = match stream.as_mut() {
            Some(rng) => backend.sample_stream(row, &seq, rng).unwrap(),
            None => backend.sample(row, &seq).unwrap(),
        };
        seq.push(t);
        if t == Vocab::EOS {
            break;
        }
    }
    seq
}

#[test]
fn mixed_length_padded_slot_matches_exact_length_generate_greedy() {
    // The tentpole golden: short prompts admitted via the padded
    // `prefill_slot` generate bit-exactly the continuation of (a) the
    // fixed-batch padded `generate_mixed` and (b) the exact-length
    // no-cache forward — for a whole batch of DIFFERENT true lengths at
    // once, greedy.
    if !padded_artifacts() {
        eprintln!("skipping: {DIR} artifacts lack padded_prompts (run `make artifacts`)");
        return;
    }
    let engine = Rc::new(Engine::cpu().unwrap());
    let mut he = HybridEngine::init(engine, DIR, 0, false).unwrap();
    let m = he.manifest();
    let (b, sp, sg) = (m.batch, m.prompt_len, m.gen_len);
    let task = TaskGen::new(m.actor.vocab, sp, sg);
    let mut rng = Rng::new(77);
    // One prompt per slot, every row a different true length (including
    // one exact-length row pinning backward compat).
    let lens: Vec<usize> = (0..b)
        .map(|i| if i + 1 == b { sp } else { (TaskGen::MIN_PROMPT_LEN + 2 * i).min(sp - 1) })
        .collect();
    let prompts: Vec<Vec<i32>> =
        lens.iter().map(|&l| task.sample_prompt_len(&mut rng, l).tokens).collect();
    let greedy = || HostFullRow::new(SamplerConfig { greedy: true, ..Default::default() }, 0);

    // Reference 1: exact-length naive full-forward loop, per prompt.
    let naive: Vec<Vec<i32>> = prompts
        .iter()
        .map(|p| naive_exact_generate(&mut he, p, sg, &mut greedy(), None))
        .collect();

    // Reference 2: the fixed-batch padded generate.
    let gen = he.generate_mixed(&prompts, &mut greedy()).unwrap();

    // The padded slot path: all prompts through the scheduler.
    let mut sched = Scheduler::new(he).unwrap();
    for (id, p) in prompts.iter().enumerate() {
        sched
            .submit(Request {
                id: id as u64,
                prompt: p.clone(),
                max_new: sg,
                seed: None,
                prefix_len: 0,
            })
            .unwrap();
    }
    let mut done = sched.run_until_idle(&mut greedy()).unwrap();
    done.sort_by_key(|c| c.id);
    assert_eq!(done.len(), b);
    for (i, c) in done.iter().enumerate() {
        assert_eq!(c.prompt_len, lens[i], "true length on the completion");
        assert_eq!(
            c.tokens, gen[i],
            "row {i} (len {}): padded slot vs fixed-batch padded generate",
            lens[i]
        );
        assert_eq!(
            c.tokens, naive[i],
            "row {i} (len {}): padded slot vs exact-length forward",
            lens[i]
        );
    }
    // The pad accounting saw the short rows.
    let st = &sched.stats;
    assert_eq!(st.prompt_tokens, lens.iter().sum::<usize>() as u64);
    assert_eq!(st.pad_tokens, lens.iter().map(|&l| (sp - l) as u64).sum::<u64>());
    assert!(st.pad_fraction() > 0.0);
}

#[test]
fn mixed_length_padded_slot_matches_exact_length_seeded_stochastic() {
    // Seeded-stochastic variant: a short request with its own RNG stream
    // must reproduce the exact-length reference drawing from the same
    // stream — even while co-scheduled with a full-length neighbor whose
    // own stream isolates it (the rollout reproducibility contract under
    // mixed lengths).
    if !padded_artifacts() {
        eprintln!("skipping: {DIR} artifacts lack padded_prompts (run `make artifacts`)");
        return;
    }
    let engine = Rc::new(Engine::cpu().unwrap());
    let mut he = HybridEngine::init(engine, DIR, 0, false).unwrap();
    let m = he.manifest();
    let (sp, sg) = (m.prompt_len, m.gen_len);
    let task = TaskGen::new(m.actor.vocab, sp, sg);
    let mut rng = Rng::new(88);
    let short = task.sample_prompt_len(&mut rng, TaskGen::MIN_PROMPT_LEN + 1).tokens;
    let full = task.sample_prompt(&mut rng).tokens;
    let cfg = SamplerConfig {
        temperature: 0.9,
        top_k: 8,
        top_p: 0.95,
        repetition_penalty: 1.1,
        ..Default::default()
    };
    let seed = 4242u64;

    // Exact-length reference over the short prompt's own stream.
    let mut stream = Rng::new(seed);
    let want = naive_exact_generate(
        &mut he,
        &short,
        sg,
        &mut HostFullRow::new(cfg.clone(), 0),
        Some(&mut stream),
    );

    let mut sched = Scheduler::new(he).unwrap();
    sched
        .submit(Request { id: 0, prompt: short, max_new: sg, seed: Some(seed), prefix_len: 0 })
        .unwrap();
    sched
        .submit(Request {
            id: 1,
            prompt: full,
            max_new: sg,
            seed: Some(seed ^ 0x5ee0),
            prefix_len: 0,
        })
        .unwrap();
    let mut done = sched.run_until_idle(&mut HostFullRow::new(cfg, 0)).unwrap();
    done.sort_by_key(|c| c.id);
    assert_eq!(done.len(), 2);
    assert_eq!(
        done[0].tokens, want,
        "seeded short request must replay its exact-length stream bit for bit"
    );
}

// ---------------------------------------------------------------------------
// Block-paged goldens: serving through the paged KV pool (per-slot block
// tables, `decode_slots_paged` gather attention) must be BIT-EXACT with the
// arena path for identical traffic — the arena path is itself pinned
// bit-exact to the exact-length forward above, so paged ≡ exact-length
// transitively. Plus the shared-prefix contract: declared-prefix admissions
// reuse registered pages without perturbing a single token.
// ---------------------------------------------------------------------------

#[test]
fn paged_serving_bit_matches_arena_for_identical_traffic() {
    // Greedy AND seeded-stochastic staggered traces: same requests, same
    // slots, same finish reasons, same tokens — the block-table gather may
    // not change one bit relative to contiguous per-slot rows.
    if !paged_artifacts() {
        eprintln!("skipping: {DIR} artifacts lack paged_kv (run `make artifacts`)");
        return;
    }
    let greedy = || HostFullRow::new(SamplerConfig { greedy: true, ..Default::default() }, 0);
    let (_, arena, _) = run_staggered_on(&mut greedy(), false);
    let (paged_sched, paged, _) = run_staggered_on(&mut greedy(), true);
    assert_eq!(arena.len(), paged.len());
    for (a, p) in arena.iter().zip(&paged) {
        assert_eq!(a.id, p.id);
        assert_eq!(a.tokens, p.tokens, "greedy req {}", a.id);
        assert_eq!(a.finish, p.finish);
        assert_eq!(a.slot, p.slot);
    }
    // No request declared a prefix: the reuse counters must stay silent
    // and every admitted token was computed.
    let st = &paged_sched.stats;
    assert_eq!(st.prefix_hits + st.prefix_misses, 0);
    assert_eq!(st.computed_tokens(), st.admitted_tokens());

    let (_, arena_s, _) = run_staggered_on(&mut golden_sampler(), false);
    let (_, paged_s, _) = run_staggered_on(&mut golden_sampler(), true);
    for (a, p) in arena_s.iter().zip(&paged_s) {
        assert_eq!(a.tokens, p.tokens, "stochastic req {}", a.id);
        assert_eq!(a.finish, p.finish);
    }
}

#[test]
fn paged_front_alignment_matches_arena_left_padding_for_mixed_lengths() {
    // Variable-length prompts: the arena admits them LEFT-padded, the
    // paged pool FRONT-aligned — two different layouts whose completions
    // must still agree bit for bit (both are pinned to the exact-length
    // computation from their own side).
    if !padded_artifacts() || !paged_artifacts() {
        eprintln!("skipping: {DIR} artifacts lack padded_prompts+paged_kv");
        return;
    }
    let engine = Rc::new(Engine::cpu().unwrap());
    let he = HybridEngine::init(engine, DIR, 0, false).unwrap();
    let m = he.manifest();
    let (b, sp, sg) = (m.batch, m.prompt_len, m.gen_len);
    let task = TaskGen::new(m.actor.vocab, sp, sg);
    let mut rng = Rng::new(99);
    let lens: Vec<usize> = (0..b + 1)
        .map(|i| if i == b { sp } else { (TaskGen::MIN_PROMPT_LEN + 2 * i).min(sp - 1) })
        .collect();
    let prompts: Vec<Vec<i32>> =
        lens.iter().map(|&l| task.sample_prompt_len(&mut rng, l).tokens).collect();
    let run = |he: HybridEngine| -> Vec<Completion> {
        let mut sched = Scheduler::new(he).unwrap();
        for (id, p) in prompts.iter().enumerate() {
            sched
                .submit(Request {
                    id: id as u64,
                    prompt: p.clone(),
                    max_new: sg,
                    seed: None,
                    prefix_len: 0,
                })
                .unwrap();
        }
        let mut greedy =
            HostFullRow::new(SamplerConfig { greedy: true, ..Default::default() }, 0);
        let mut done = sched.run_until_idle(&mut greedy).unwrap();
        done.sort_by_key(|c| c.id);
        done
    };
    let arena = run(he);
    let engine = Rc::new(Engine::cpu().unwrap());
    let mut he = HybridEngine::init(engine, DIR, 0, false).unwrap();
    he.use_paged_serving(true).unwrap();
    let paged = run(he);
    assert_eq!(arena.len(), paged.len());
    for (a, p) in arena.iter().zip(&paged) {
        assert_eq!(a.prompt_len, p.prompt_len);
        assert_eq!(a.tokens, p.tokens, "req {} (len {})", a.id, a.prompt_len);
        assert_eq!(a.finish, p.finish);
    }
}

#[test]
fn shared_prefix_reuse_is_bit_identical_and_counted() {
    // The shared-prefix golden: requests declaring a common page-aligned
    // system prompt map its registered pages instead of recomputing them —
    // completions stay bit-identical to an independent (no-sharing) run,
    // while the scheduler reports the reuse (computed < admitted, nonzero
    // hit rate).
    if !paged_artifacts() {
        eprintln!("skipping: {DIR} artifacts lack paged_kv (run `make artifacts`)");
        return;
    }
    let engine = Rc::new(Engine::cpu().unwrap());
    let mut he = HybridEngine::init(engine, DIR, 0, false).unwrap();
    he.use_paged_serving(true).unwrap();
    let m = he.manifest();
    let (sp, sg) = (m.prompt_len, m.gen_len);
    let share = (sp / m.page_size) * m.page_size;
    if share == 0 {
        eprintln!("skipping: prompt_len {sp} < page_size {} shares nothing", m.page_size);
        return;
    }
    assert!(m.batch >= 3, "test wants 3 concurrent slots, batch is {}", m.batch);
    let task = TaskGen::new(m.actor.vocab, sp, sg);
    let mut rng = Rng::new(123);
    // One shared prompt for everyone (prompt_len == page_size in the tiny
    // geometry, so the share-able region is the whole prompt).
    let prompt = task.sample_prompt(&mut rng).tokens;
    let greedy = || HostFullRow::new(SamplerConfig { greedy: true, ..Default::default() }, 0);

    // Independent reference: same prompt, no declared prefix.
    let mut solo = Scheduler::new(he).unwrap();
    solo.submit(Request { id: 0, prompt: prompt.clone(), max_new: sg, seed: None, prefix_len: 0 })
        .unwrap();
    let want = solo.run_until_idle(&mut greedy()).unwrap().remove(0).tokens;
    assert_eq!(solo.stats.prefix_hits + solo.stats.prefix_misses, 0);

    // Shared run: three admissions declaring the prefix, same step.
    let engine = Rc::new(Engine::cpu().unwrap());
    let mut he = HybridEngine::init(engine, DIR, 0, false).unwrap();
    he.use_paged_serving(true).unwrap();
    let mut sched = Scheduler::new(he).unwrap();
    for id in 0..3u64 {
        sched
            .submit(Request {
                id,
                prompt: prompt.clone(),
                max_new: sg,
                seed: None,
                prefix_len: share,
            })
            .unwrap();
    }
    let mut done = sched.run_until_idle(&mut greedy()).unwrap();
    done.sort_by_key(|c| c.id);
    assert_eq!(done.len(), 3);
    for c in &done {
        assert_eq!(c.tokens, want, "req {}: sharing must not move a single token", c.id);
    }
    let st = &sched.stats;
    assert_eq!(st.prefix_misses, 1, "first admission registers");
    assert_eq!(st.prefix_hits, 2, "the other two map the registered pages");
    assert_eq!(st.reused_tokens, 2 * share as u64);
    assert!(st.computed_tokens() < st.admitted_tokens(), "{st:?}");
    assert!((st.cache_hit_rate() - 2.0 / 3.0).abs() < 1e-12);
}
