//! Integration: the continuous-batching serving path over real AOT
//! artifacts (requires `make artifacts` with the `prefill_slot` /
//! `decode_slots` entries). Each test passes vacuously when artifacts are
//! missing or predate the serving entry points, so tier-1 stays green on a
//! bare checkout; the scheduler's policy logic is covered without
//! artifacts by the unit tests in `rust/src/serving/mod.rs`.

use std::rc::Rc;

use dschat::data::synthetic::TaskGen;
use dschat::hybrid::HybridEngine;
use dschat::runtime::{Engine, Manifest};
use dschat::sampling::{Sampler, SamplerConfig};
use dschat::serving::{Completion, Request, Scheduler};
use dschat::util::rng::Rng;

const DIR: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/tiny");

fn serving_artifacts() -> bool {
    match Manifest::load(DIR) {
        Ok(m) => {
            m.artifacts.contains_key("prefill_slot") && m.artifacts.contains_key("decode_slots")
        }
        Err(_) => false,
    }
}

fn golden_sampler() -> Sampler {
    Sampler::new(
        SamplerConfig {
            temperature: 0.9,
            top_k: 8,
            top_p: 0.95,
            repetition_penalty: 1.1,
            ..Default::default()
        },
        7,
    )
}

/// Build a scheduler, submit `b + 2` requests with a staggered pattern
/// (two up front, the rest after one step), run to idle, and return the
/// scheduler plus completions sorted by id and the prompts used.
fn run_staggered() -> (Scheduler<HybridEngine>, Vec<Completion>, Vec<Vec<i32>>) {
    let engine = Rc::new(Engine::cpu().unwrap());
    let he = HybridEngine::init(engine, DIR, 0, false).unwrap();
    let m = he.manifest();
    let (b, sp, sg) = (m.batch, m.prompt_len, m.gen_len);
    let task = TaskGen::new(m.actor.vocab, sp, sg);
    let mut rng = Rng::new(41);
    let prompts: Vec<Vec<i32>> =
        (0..b + 2).map(|_| task.sample_prompt(&mut rng).tokens).collect();

    let mut sched = Scheduler::new(he).unwrap();
    let mut sampler = golden_sampler();
    let mut done = Vec::new();
    for (id, p) in prompts.iter().enumerate().take(2) {
        sched.submit(Request { id: id as u64, prompt: p.clone(), max_new: sg }).unwrap();
    }
    done.extend(sched.step(&mut sampler).unwrap());
    for (id, p) in prompts.iter().enumerate().skip(2) {
        sched.submit(Request { id: id as u64, prompt: p.clone(), max_new: sg }).unwrap();
    }
    done.extend(sched.run_until_idle(&mut sampler).unwrap());
    done.sort_by_key(|c| c.id);
    (sched, done, prompts)
}

#[test]
fn staggered_serving_completes_all_and_preserves_prompts() {
    if !serving_artifacts() {
        eprintln!("skipping: {DIR} missing serving artifacts (run `make artifacts`)");
        return;
    }
    let (sched, done, prompts) = run_staggered();
    let b = sched.engine.manifest().batch;
    let sg = sched.engine.manifest().gen_len;
    assert_eq!(done.len(), b + 2, "every request completes");
    for (id, c) in done.iter().enumerate() {
        assert_eq!(c.id, id as u64);
        // Prompt region copied verbatim into the sequence.
        assert_eq!(&c.tokens[..c.prompt_len], prompts[id].as_slice(), "req {id}");
        assert!(c.generated >= 1 && c.generated <= sg, "req {id}: {}", c.generated);
        assert_eq!(c.tokens.len(), c.prompt_len + c.generated);
    }
    // More requests than slots forces queueing and slot reuse.
    assert_eq!(sched.stats.admitted as usize, b + 2);
    assert_eq!(sched.stats.prefills as usize, b + 2);
    assert!(sched.stats.peak_queue_depth >= 2, "{}", sched.stats.peak_queue_depth);
    assert!(done.iter().any(|c| c.queued_steps > 0), "someone must have waited");
    assert!(sched.is_idle());
    // The engine counted the serving tokens in its generation ledger.
    let total: usize = done.iter().map(|c| c.generated).sum();
    assert_eq!(sched.engine.stats.gen_tokens as usize, total);
}

#[test]
fn serving_path_is_bit_deterministic() {
    // The continuous-batching analogue of the PR 1 generate golden: the
    // same request trace through a fresh engine must reproduce the exact
    // token sequences (device-resident per-slot decode can't perturb
    // sampling inputs).
    if !serving_artifacts() {
        eprintln!("skipping: {DIR} missing serving artifacts (run `make artifacts`)");
        return;
    }
    let (_, first, _) = run_staggered();
    let (_, again, _) = run_staggered();
    assert_eq!(first.len(), again.len());
    for (a, b) in first.iter().zip(&again) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.tokens, b.tokens, "req {}", a.id);
        assert_eq!(a.finish, b.finish);
    }
}

#[test]
fn serving_cache_accounting_survives_generate_reentry() {
    // The serving cache participates in the same alloc/free ledger as the
    // batch path: generate() after a serving session replaces the cache
    // without double-counting.
    if !serving_artifacts() {
        eprintln!("skipping: {DIR} missing serving artifacts (run `make artifacts`)");
        return;
    }
    let (sched, _, _) = run_staggered();
    let mut he = sched.engine;
    let kv_live = he.memory.live_named("kv_cache");
    assert!(kv_live > 0, "serving cache must be tracked");
    let m = he.manifest();
    let (b, sp, sg) = (m.batch, m.prompt_len, m.gen_len);
    let task = TaskGen::new(m.actor.vocab, sp, sg);
    let mut rng = Rng::new(5);
    let mut flat = Vec::with_capacity(b * sp);
    for _ in 0..b {
        flat.extend_from_slice(&task.sample_prompt(&mut rng).tokens);
    }
    let mut sampler = Sampler::new(SamplerConfig { greedy: true, ..Default::default() }, 0);
    he.generate(&flat, &mut sampler).unwrap();
    assert_eq!(he.memory.live_named("kv_cache"), kv_live, "re-entry double-counted kv");
}
