//! Integration: the full 3-step RLHF pipeline at `tiny` scale through the
//! hybrid engine (requires `make artifacts`). This is the rust-side
//! counterpart of the paper's single-script experience.

use std::rc::Rc;

use dschat::config::{PpoConfig, TrainRecipe};
use dschat::coordinator::PpoTrainer;
use dschat::data::synthetic::TaskGen;
use dschat::data::{Blend, DataSplit};
use dschat::hybrid::{EngineMode, HybridEngine};
use dschat::pipeline;
use dschat::runtime::{Engine, Manifest};
use dschat::sampling::{DeviceTopK, HostFullRow, SamplerConfig};
use dschat::util::rng::Rng;

const DIR: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/tiny");

/// The scheduler-rollout tests additionally need the serving entry points;
/// stale artifact dirs skip them with a re-run hint instead of failing.
fn serving_artifacts() -> bool {
    Manifest::load(DIR).map(|m| m.has_serving()).unwrap_or(false)
}

fn setup(with_ema: bool) -> (HybridEngine, Blend) {
    let engine = Rc::new(Engine::cpu().unwrap());
    let he = HybridEngine::init(engine, DIR, 0, with_ema).unwrap();
    let m = he.manifest();
    let task = TaskGen::new(m.actor.vocab, m.prompt_len, m.gen_len);
    let blend = Blend::new(vec![(task, 1.0)], DataSplit::new(2.0, 4.0, 4.0));
    (he, blend)
}

#[test]
fn generation_respects_shapes_and_prompts() {
    let (mut he, mut blend) = setup(false);
    let m = he.manifest();
    let (b, sp, s) = (m.batch, m.prompt_len, m.seq_len);
    let mut rng = Rng::new(1);
    let prompts = blend.prompt_batch(&mut rng, b);
    let mut flat = Vec::new();
    for (_, p) in &prompts {
        flat.extend_from_slice(&p.tokens);
    }
    let mut sampler = HostFullRow::new(SamplerConfig::default(), 0);
    let seqs = he.generate(&flat, &mut sampler).unwrap();
    assert_eq!(seqs.len(), b * s);
    // Prompt region must be copied verbatim.
    for i in 0..b {
        assert_eq!(&seqs[i * s..i * s + sp], &flat[i * sp..(i + 1) * sp]);
    }
    assert_eq!(he.mode(), EngineMode::Inference);
    assert!(he.stats.gen_tokens > 0);
    assert!(he.memory.live_named("kv_cache") > 0, "KV pool must be live in inference mode");
}

#[test]
fn mode_flip_releases_kv_cache() {
    let (mut he, mut blend) = setup(false);
    let b = he.manifest().batch;
    let mut rng = Rng::new(2);
    let prompts = blend.prompt_batch(&mut rng, b);
    let mut flat = Vec::new();
    for (_, p) in &prompts {
        flat.extend_from_slice(&p.tokens);
    }
    let mut sampler = HostFullRow::new(SamplerConfig::default(), 0);
    he.generate(&flat, &mut sampler).unwrap();
    let kv_live = he.memory.live_named("kv_cache");
    assert!(kv_live > 0);

    // A train step flips the engine to training mode -> KV pool released.
    let batch = blend.sft_batch(&mut rng, b);
    he.sft_step(&batch, 1e-3).unwrap();
    assert_eq!(he.mode(), EngineMode::Train);
    assert_eq!(he.memory.live_named("kv_cache"), 0);
    assert!(he.stats.mode_flips >= 2);
    // Peak memory saw params + opt + kv simultaneously.
    assert!(he.memory.peak_bytes() > he.memory.live_bytes());
}

#[test]
fn ppo_iteration_produces_finite_stats() {
    let (mut he, mut blend) = setup(true);
    let mut rng = Rng::new(3);
    // A short SFT warmup so generation isn't uniform noise.
    let recipe = TrainRecipe { sft_steps: 10, ..Default::default() };
    pipeline::run_sft(&mut he, &mut blend, &recipe, &mut rng, None).unwrap();

    let mut trainer = PpoTrainer::new(PpoConfig { ppo_epochs: 1, ..Default::default() }, 9);
    let stats = trainer
        .iteration(&mut he, &mut blend, &mut rng, 1e-4, 5e-4)
        .unwrap();
    assert!(stats.true_reward.is_finite());
    assert!((0.0..=1.0).contains(&stats.true_reward), "{}", stats.true_reward);
    assert!(stats.rm_score.is_finite());
    assert!(stats.actor_loss.is_finite());
    assert!(stats.critic_loss.is_finite());
    assert!(stats.clipfrac >= 0.0 && stats.clipfrac <= 1.0);
    assert!(stats.gen_tokens > 0);
}

#[test]
fn three_step_pipeline_smoke_learns() {
    let (mut he, mut blend) = setup(true);
    let recipe = TrainRecipe {
        sft_steps: 400,
        sft_lr: 1e-2,
        rm_steps: 150,
        rm_lr: 3e-3,
        ppo_iters: 3,
        actor_lr: 1e-4,
        critic_lr: 5e-4,
        ppo: PpoConfig { ppo_epochs: 1, ..Default::default() },
        ..Default::default()
    };
    let report = pipeline::run_all(&mut he, &mut blend, &recipe, None).unwrap();

    // Step 1: SFT loss must fall substantially from ~log(vocab). The tail
    // mean over batch-4 losses is noisy at tiny scale, so the bound is
    // deliberately loose (the e2e example at `small` scale pins 6.0 -> 0.7).
    assert!(
        report.sft.last_metric < report.sft.first_metric * 0.75,
        "sft: {} -> {}",
        report.sft.first_metric,
        report.sft.last_metric
    );
    // Step 2: RM pairwise accuracy must beat chance clearly.
    assert!(report.rm.extra > 0.7, "rm held-out acc {}", report.rm.extra);
    // Step 3 ran and produced sane rewards.
    assert_eq!(report.ppo_history.len(), 3);
    for it in &report.ppo_history {
        assert!((0.0..=1.0).contains(&it.true_reward));
    }
    // Both phases of step 3 were exercised through the hybrid engine.
    assert!(he.stats.gen_secs > 0.0 && he.stats.train_secs > 0.0);
}

#[test]
fn kv_accounting_balanced_across_generate_train_cycles() {
    // Regression: the kv_cache alloc/free pairing must survive inference
    // re-entry (generate→generate replaces the live cache without a train
    // flip) and early EOS exits; a generate→train→generate→train cycle
    // must leave tracked bytes exactly where they started.
    let (mut he, mut blend) = setup(false);
    let b = he.manifest().batch;
    let mut rng = Rng::new(11);
    let prompts = blend.prompt_batch(&mut rng, b);
    let mut flat = Vec::new();
    for (_, p) in &prompts {
        flat.extend_from_slice(&p.tokens);
    }
    let mut sampler = HostFullRow::new(SamplerConfig::default(), 0);
    let baseline = he.memory.live_bytes();

    he.generate(&flat, &mut sampler).unwrap();
    let kv_live = he.memory.live_named("kv_cache");
    assert!(kv_live > 0);
    // Inference re-entry: the replaced cache must not double-count.
    he.generate(&flat, &mut sampler).unwrap();
    assert_eq!(he.memory.live_named("kv_cache"), kv_live, "re-entry double-counted kv");

    let batch = blend.sft_batch(&mut rng, b);
    he.sft_step(&batch, 1e-3).unwrap();
    assert_eq!(he.memory.live_named("kv_cache"), 0);
    assert_eq!(he.memory.live_bytes(), baseline, "cycle leaked tracked bytes");

    he.generate(&flat, &mut sampler).unwrap();
    he.sft_step(&batch, 1e-3).unwrap();
    assert_eq!(he.memory.live_bytes(), baseline, "second cycle leaked tracked bytes");
}

#[test]
fn generate_is_bit_identical_for_fixed_seed() {
    // Golden determinism: with a fixed sampler seed, generate must produce
    // bit-identical sequences across repeated calls on one engine AND on a
    // freshly built engine (the zero-copy decode path can't perturb
    // sampling inputs).
    let cfg = SamplerConfig {
        temperature: 0.9,
        top_k: 8,
        top_p: 0.95,
        repetition_penalty: 1.1,
        ..Default::default()
    };
    let (mut he, mut blend) = setup(false);
    let b = he.manifest().batch;
    let mut rng = Rng::new(21);
    let prompts = blend.prompt_batch(&mut rng, b);
    let mut flat = Vec::new();
    for (_, p) in &prompts {
        flat.extend_from_slice(&p.tokens);
    }
    let first = he.generate(&flat, &mut HostFullRow::new(cfg.clone(), 7)).unwrap();
    let again = he.generate(&flat, &mut HostFullRow::new(cfg.clone(), 7)).unwrap();
    assert_eq!(first, again, "same engine, same seed must be bit-identical");

    let (mut he2, _) = setup(false);
    let fresh = he2.generate(&flat, &mut HostFullRow::new(cfg, 7)).unwrap();
    assert_eq!(first, fresh, "fresh engine, same seed must be bit-identical");
}

#[test]
fn device_greedy_generation_matches_host_argmax_golden() {
    // The device-sampling extension of the PR 1 golden: greedy generation
    // through the `_sampled` artifacts (argmax on device, [b] ids fetched
    // per step) must be bit-identical to the host full-row argmax path
    // (both tie-break toward the lower token id). Vacuous when the
    // artifact set predates device-side sampling.
    let (mut he, mut blend) = setup(false);
    if !he.manifest().artifacts.contains_key("decode_step_sampled") {
        eprintln!("skipping: artifacts predate device-side sampling (run `make artifacts`)");
        return;
    }
    let b = he.manifest().batch;
    let mut rng = Rng::new(31);
    let prompts = blend.prompt_batch(&mut rng, b);
    let mut flat = Vec::new();
    for (_, p) in &prompts {
        flat.extend_from_slice(&p.tokens);
    }
    let greedy = SamplerConfig { greedy: true, ..Default::default() };
    let host = he.generate(&flat, &mut HostFullRow::new(greedy.clone(), 0)).unwrap();
    let mut device = DeviceTopK::for_manifest(greedy.clone(), 0, he.manifest()).unwrap();
    let dev = he.generate(&flat, &mut device).unwrap();
    assert_eq!(host, dev, "device argmax must reproduce host argmax bit-exactly");
    // And on a fresh engine (no shared-cache coupling).
    let (mut he2, _) = setup(false);
    let mut device2 = DeviceTopK::for_manifest(greedy, 0, he2.manifest()).unwrap();
    let fresh = he2.generate(&flat, &mut device2).unwrap();
    assert_eq!(host, fresh);
}

#[test]
fn staged_ppo_epochs_match_unstaged_and_cut_uploads() {
    // Satellite contract: staging the experience batch once per PPO batch
    // must (a) be numerically identical to re-uploading per epoch and
    // (b) strictly shrink the bytes-uploaded counter for multi-epoch runs.
    let (mut he, _) = setup(false);
    let m = he.manifest();
    let (b, s) = (m.batch, m.seq_len);
    let w = b * (s - 1);
    let mut tokens = vec![0i32; b * s];
    for (i, t) in tokens.iter_mut().enumerate() {
        *t = ((i * 11 + 2) % m.actor.vocab) as i32;
    }
    let old_logp = vec![-1.0f32; w];
    let adv = vec![0.1f32; w];
    let returns = vec![0.2f32; w];
    let old_values = vec![0.15f32; w];
    let mask = vec![1.0f32; w];

    // Unstaged epoch pair on one engine...
    he.engine.reset_stats();
    let mut legacy = Vec::new();
    for _ in 0..2 {
        let out = he
            .ppo_actor_step(&tokens, &old_logp, &adv, &mask, &tokens, 0.2, 0.0, 1e-4)
            .unwrap();
        let closs = he
            .ppo_critic_step(&tokens, &returns, &old_values, &mask, 0.2, 5e-4)
            .unwrap();
        legacy.push((out.loss, out.approx_kl, out.clipfrac, closs));
    }
    let (legacy_up, _) = he.engine.bytes_moved();

    // ...staged epoch pair on a fresh engine (identical initial state).
    let (mut he2, _) = setup(false);
    he2.engine.reset_stats();
    let staged = he2
        .stage_experience(&tokens, &old_logp, &adv, &returns, &old_values, &mask)
        .unwrap();
    let mut staged_out = Vec::new();
    for _ in 0..2 {
        let out = he2.ppo_actor_step_staged(&staged, &tokens, 0.2, 0.0, 1e-4).unwrap();
        let closs = he2.ppo_critic_step_staged(&staged, 0.2, 5e-4).unwrap();
        staged_out.push((out.loss, out.approx_kl, out.clipfrac, closs));
    }
    let (staged_up, _) = he2.engine.bytes_moved();

    assert_eq!(legacy, staged_out, "staging must not change the math");
    assert!(
        staged_up < legacy_up,
        "staged epochs must upload fewer bytes: {staged_up} vs {legacy_up}"
    );
}

#[test]
fn generate_experience_rejects_wrong_prompt_count() {
    // The fixed path's batch/artifact mismatch is a config error pointing
    // at rollout_batch, not a panic.
    let (mut he, mut blend) = setup(false);
    let b = he.manifest().batch;
    let mut rng = Rng::new(5);
    let prompts = blend.prompt_batch(&mut rng, b + 1);
    let mut trainer = PpoTrainer::new(PpoConfig::default(), 3);
    let err = trainer.generate_experience(&mut he, &prompts).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("rollout_batch"), "{msg}");
    // And the rollout path rejects non-multiples the same way.
    let err = trainer.generate_experience_rollout(&mut he, &prompts).unwrap_err();
    assert!(format!("{err:#}").contains("multiple"), "{err:#}");
}

#[test]
fn scheduler_rollout_greedy_matches_fixed_generate_golden() {
    // The rollout golden: for b equal-length prompts under greedy
    // decoding, the continuous-batching rollout must produce the SAME
    // experience as fixed-batch generate, bit for bit — tokens, response
    // lengths, and every scored tensor. Proves the per-slot serving
    // artifacts and the scheduler introduce no drift vs the lockstep path.
    let (mut he, mut blend) = setup(false);
    if !serving_artifacts() {
        eprintln!("skipping: {DIR} missing serving artifacts (run `make artifacts`)");
        return;
    }
    let b = he.manifest().batch;
    let mut rng = Rng::new(31);
    let prompts = blend.prompt_batch(&mut rng, b);
    let greedy = SamplerConfig { greedy: true, ..Default::default() };
    let mut fixed_tr = PpoTrainer::with_backend(
        PpoConfig::default(),
        Box::new(HostFullRow::new(greedy.clone(), 0)),
        0,
    );
    let exp_fixed = fixed_tr.generate_experience(&mut he, &prompts).unwrap();
    let mut roll_tr =
        PpoTrainer::with_backend(PpoConfig::default(), Box::new(HostFullRow::new(greedy, 0)), 0);
    let (exps, stats) = roll_tr.generate_experience_rollout(&mut he, &prompts).unwrap();
    assert_eq!(exps.len(), 1, "b prompts flush exactly one experience batch");
    let exp_roll = &exps[0];
    assert_eq!(
        exp_fixed.tokens, exp_roll.tokens,
        "scheduler rollout must reproduce fixed-batch generate bit-exactly"
    );
    assert_eq!(exp_fixed.resp_lens, exp_roll.resp_lens);
    assert_eq!(exp_fixed.rm_scores, exp_roll.rm_scores);
    assert_eq!(exp_fixed.true_rewards, exp_roll.true_rewards);
    assert_eq!(exp_fixed.old_logp, exp_roll.old_logp);
    assert_eq!(exp_fixed.old_values, exp_roll.old_values);
    assert_eq!(exp_fixed.advantages, exp_roll.advantages);
    assert_eq!(exp_fixed.returns, exp_roll.returns);
    assert_eq!(exp_fixed.mask, exp_roll.mask);
    assert_eq!(stats.prefills as usize, b, "every prompt admitted once");
}

#[test]
fn rollout_batch_above_artifact_batch_trains_through_scheduler() {
    // The tentpole acceptance: PPO trains with rollout_batch > b — the
    // prompt queue oversubscribes the slots and each flushed group of b
    // completions becomes its own training batch.
    let (mut he, mut blend) = setup(true);
    if !serving_artifacts() {
        eprintln!("skipping: {DIR} missing serving artifacts (run `make artifacts`)");
        return;
    }
    let mut rng = Rng::new(3);
    let recipe = TrainRecipe { sft_steps: 10, ..Default::default() };
    pipeline::run_sft(&mut he, &mut blend, &recipe, &mut rng, None).unwrap();
    let b = he.manifest().batch;
    let cfg = PpoConfig { ppo_epochs: 1, rollout_batch: 2 * b, ..Default::default() };
    let mut trainer = PpoTrainer::new(cfg, 9);
    let stats = trainer.iteration(&mut he, &mut blend, &mut rng, 1e-4, 5e-4).unwrap();
    assert_eq!(stats.rollout_groups, 2, "2b prompts flush two training batches");
    assert!(stats.true_reward.is_finite());
    assert!((0.0..=1.0).contains(&stats.true_reward), "{}", stats.true_reward);
    assert!(stats.rm_score.is_finite());
    assert!(stats.actor_loss.is_finite());
    assert!(stats.critic_loss.is_finite());
    assert!(stats.gen_tokens > 0);
    assert!(
        (0.0..1.0).contains(&stats.rollout_bubble),
        "bubble fraction out of range: {}",
        stats.rollout_bubble
    );
}

#[test]
fn stochastic_rollout_is_reproducible_across_runs() {
    // Per-request derived RNG streams: the same prompts, base seed, and
    // params reproduce every sampled sequence bit for bit even though
    // retirement order (and hence sample-call interleaving) is
    // data-dependent.
    let (mut he, mut blend) = setup(false);
    if !serving_artifacts() {
        eprintln!("skipping: {DIR} missing serving artifacts (run `make artifacts`)");
        return;
    }
    let b = he.manifest().batch;
    let mut rng = Rng::new(41);
    let prompts = blend.prompt_batch(&mut rng, 2 * b);
    let cfg = PpoConfig { temperature: 0.9, top_p: 0.95, ..Default::default() };
    let mut t1 = PpoTrainer::new(cfg.clone(), 17);
    let (e1, _) = t1.generate_experience_rollout(&mut he, &prompts).unwrap();
    let mut t2 = PpoTrainer::new(cfg, 17);
    let (e2, _) = t2.generate_experience_rollout(&mut he, &prompts).unwrap();
    assert_eq!(e1.len(), e2.len());
    for (a, b) in e1.iter().zip(&e2) {
        assert_eq!(a.tokens, b.tokens, "stochastic rollout must be replayable");
        assert_eq!(a.resp_lens, b.resp_lens);
    }
    // ...while a SECOND rollout on the same trainer derives a fresh round
    // seed and must not replay round 0's draws (decorrelated iterations).
    let (e3, _) = t1.generate_experience_rollout(&mut he, &prompts).unwrap();
    assert_ne!(
        e1[0].tokens, e3[0].tokens,
        "consecutive rollout rounds must not replay each other's streams"
    );
}

#[test]
fn training_snapshot_restore_roundtrips_bitwise() {
    // The anomaly guard's rollback primitive: snapshot → scramble →
    // restore must put params AND optimizer state back bit for bit (a
    // rolled-back iteration replays against exactly the pre-trip state).
    let (mut he, mut blend) = setup(true);
    let mut rng = Rng::new(51);
    let b = he.manifest().batch;
    // Move off init so the snapshot is non-trivial (params + Adam moments).
    let batch = blend.sft_batch(&mut rng, b);
    he.sft_step(&batch, 1e-3).unwrap();
    let snap = he.snapshot_training_state().unwrap();
    let actor0 = he.actor.to_host().unwrap();
    let opt0 = he.actor_opt.to_host().unwrap();

    let batch2 = blend.sft_batch(&mut rng, b);
    he.sft_step(&batch2, 5e-2).unwrap();
    assert_ne!(actor0, he.actor.to_host().unwrap(), "scramble must move the params");

    he.restore_training_state(&snap).unwrap();
    assert_eq!(actor0, he.actor.to_host().unwrap(), "actor params restored bitwise");
    assert_eq!(opt0, he.actor_opt.to_host().unwrap(), "optimizer state restored bitwise");
}

#[test]
fn anomaly_guard_rolls_back_injected_nan_and_stays_finite() {
    // The training-layer chaos drill: a NaN actor loss injected at
    // iteration 1 must trip the guard, roll the trainer back to the
    // snapshot, and re-roll to a healthy iteration — every returned stats
    // row is finite and the trip is visible on the counter.
    let (mut he, mut blend) = setup(true);
    let mut rng = Rng::new(3);
    let recipe = TrainRecipe { sft_steps: 10, ..Default::default() };
    pipeline::run_sft(&mut he, &mut blend, &recipe, &mut rng, None).unwrap();

    let cfg = PpoConfig { ppo_epochs: 1, fault_iteration: Some(1), ..Default::default() };
    let mut trainer = PpoTrainer::new(cfg, 9);
    for iter in 0..3 {
        let stats = trainer
            .iteration_guarded(&mut he, &mut blend, &mut rng, 1e-4, 5e-4)
            .unwrap();
        assert!(stats.actor_loss.is_finite(), "iter {iter}: {}", stats.actor_loss);
        assert!(stats.critic_loss.is_finite(), "iter {iter}: {}", stats.critic_loss);
        assert!(stats.approx_kl.is_finite(), "iter {iter}");
    }
    assert_eq!(trainer.guard_trips, 1, "the injected NaN tripped the guard exactly once");
}

#[test]
fn ppo_checkpoint_roundtrip_restores_run_state_and_params() {
    // The durable-resume primitive: save_ppo_checkpoint carries all six
    // stores + the run state; loading restores the params bitwise and
    // hands back the exact counters.
    let (mut he, mut blend) = setup(true);
    let mut rng = Rng::new(61);
    let b = he.manifest().batch;
    let batch = blend.sft_batch(&mut rng, b);
    he.sft_step(&batch, 1e-3).unwrap();
    let actor0 = he.actor.to_host().unwrap();
    let critic0 = he.critic.to_host().unwrap();

    let (rng_state, rng_inc) = rng.state();
    let state = pipeline::checkpoint::RunState {
        iteration: 7,
        rng_state,
        rng_inc,
        rollouts_done: 3,
        ema_phase: 7,
    };
    let path = std::env::temp_dir().join("dschat_it_ckpt/ppo_ckpt.bin");
    pipeline::save_ppo_checkpoint(&he, &state, &path).unwrap();

    // Scramble, then resume-load into the same engine.
    let batch2 = blend.sft_batch(&mut rng, b);
    he.sft_step(&batch2, 5e-2).unwrap();
    assert_ne!(actor0, he.actor.to_host().unwrap());
    let loaded = pipeline::load_ppo_checkpoint(&mut he, &path).unwrap();
    assert_eq!(loaded, state, "run state survives the tensor encoding");
    assert_eq!(actor0, he.actor.to_host().unwrap(), "actor restored bitwise");
    assert_eq!(critic0, he.critic.to_host().unwrap(), "critic restored bitwise");
    // The restored RNG stream resumes mid-sequence.
    let mut resumed = Rng::from_state(loaded.rng_state, loaded.rng_inc);
    assert_eq!(rng.below(1 << 30), resumed.below(1 << 30));
}

#[test]
fn checkpoint_roundtrip_preserves_actor() {
    let (mut he, mut blend) = setup(false);
    let mut rng = Rng::new(4);
    // Perturb the actor away from init.
    let batch = blend.sft_batch(&mut rng, he.manifest().batch);
    he.sft_step(&batch, 1e-3).unwrap();
    let before = he.actor.to_host().unwrap();

    let path = std::env::temp_dir().join("dschat_it_ckpt/actor.bin");
    pipeline::save_actor(&he, &path).unwrap();

    // Scramble the live actor, then restore.
    let batch2 = blend.sft_batch(&mut rng, he.manifest().batch);
    he.sft_step(&batch2, 5e-2).unwrap();
    assert_ne!(before, he.actor.to_host().unwrap());
    pipeline::load_actor(&mut he, &path).unwrap();
    assert_eq!(before, he.actor.to_host().unwrap());
}
