//! Failure injection: every user-facing surface must fail loudly and
//! helpfully, never corrupt state. No artifacts required except where noted.

use dschat::pipeline::checkpoint;
use dschat::runtime::{HostTensor, Manifest};
use dschat::util::json::Json;

fn tmp(name: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join("dschat_failure_tests");
    std::fs::create_dir_all(&d).unwrap();
    d.join(name)
}

// ---------------------------------------------------------------------------
// manifest failures
// ---------------------------------------------------------------------------

#[test]
fn manifest_missing_dir_mentions_make_artifacts() {
    let err = Manifest::load("/no/such/dir").unwrap_err();
    assert!(format!("{err:#}").contains("make artifacts"));
}

#[test]
fn manifest_invalid_json_reports_position() {
    let d = tmp("bad_json");
    std::fs::create_dir_all(&d).unwrap();
    std::fs::write(d.join("manifest.json"), "{\"run\": ").unwrap();
    let err = Manifest::load(&d).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("json error"), "{msg}");
}

#[test]
fn manifest_missing_key_panics_with_key_name() {
    let d = tmp("missing_key");
    std::fs::create_dir_all(&d).unwrap();
    std::fs::write(d.join("manifest.json"), r#"{"run": "x"}"#).unwrap();
    let res = std::panic::catch_unwind(|| Manifest::load(&d));
    // `at()` panics naming the missing key — acceptable loud failure.
    assert!(res.is_err() || res.unwrap().is_err());
}

#[test]
fn manifest_validate_catches_inconsistent_shapes() {
    // seq_len != prompt+gen must be rejected.
    let d = tmp("bad_seq");
    std::fs::create_dir_all(&d).unwrap();
    let text = r#"{
      "run": "bad",
      "config": {
        "batch": 2, "prompt_len": 4, "gen_len": 4, "seq_len": 9,
        "actor": {"name":"a","vocab":16,"d_model":8,"n_layers":1,"n_heads":2,"d_ff":16,"max_seq":8},
        "critic": {"name":"c","vocab":16,"d_model":8,"n_layers":1,"n_heads":2,"d_ff":16,"max_seq":8}
      },
      "actor_params": [], "critic_params": [],
      "actor_opt": [], "critic_opt": [],
      "artifacts": {}
    }"#;
    std::fs::write(d.join("manifest.json"), text).unwrap();
    let m = Manifest::load(&d).unwrap();
    let err = m.validate().unwrap_err();
    assert!(format!("{err}").contains("seq_len"));
}

// ---------------------------------------------------------------------------
// checkpoint failures
// ---------------------------------------------------------------------------

#[test]
fn checkpoint_truncated_file_errors() {
    let path = tmp("trunc.bin");
    checkpoint::save(
        &path,
        &[("w".to_string(), HostTensor::F32(vec![1.0; 100], vec![100]))],
    )
    .unwrap();
    let bytes = std::fs::read(&path).unwrap();
    std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
    assert!(checkpoint::load(&path).is_err());
}

#[test]
fn checkpoint_wrong_magic_errors() {
    let path = tmp("magic.bin");
    std::fs::write(&path, b"NOTACKPTxxxxxxxxxxxxxxxx").unwrap();
    let err = checkpoint::load(&path).unwrap_err();
    assert!(format!("{err}").contains("magic"));
}

#[test]
fn checkpoint_absurd_name_length_rejected() {
    let path = tmp("absurd.bin");
    let mut bytes = b"DSCHKPT1".to_vec();
    bytes.extend((1u32).to_le_bytes()); // one tensor
    bytes.extend((u32::MAX).to_le_bytes()); // name_len = 4 GiB
    std::fs::write(&path, &bytes).unwrap();
    let err = checkpoint::load(&path).unwrap_err();
    assert!(format!("{err}").contains("corrupt"), "{err}");
}

// ---------------------------------------------------------------------------
// tensor / json edges
// ---------------------------------------------------------------------------

#[test]
fn host_tensor_type_confusion_errors() {
    let t = HostTensor::I32(vec![1, 2], vec![2]);
    assert!(t.as_f32().is_err());
    assert!(t.item_f32().is_err());
    let f = HostTensor::F32(vec![1.0, 2.0], vec![2]);
    assert!(f.as_i32().is_err());
}

#[test]
fn json_depth_and_garbage() {
    // Deep nesting parses fine (no recursion blowup at sane depths).
    let deep = format!("{}1{}", "[".repeat(200), "]".repeat(200));
    assert!(Json::parse(&deep).is_ok());
    for garbage in ["", "nul", "{\"a\":}", "[1 2]", "\"\\q\"", "tru"] {
        assert!(Json::parse(garbage).is_err(), "{garbage:?} should fail");
    }
}

// ---------------------------------------------------------------------------
// simulator failure surfaces
// ---------------------------------------------------------------------------

#[test]
fn simulator_returns_oom_not_nonsense() {
    use dschat::baselines::hf_ddp;
    use dschat::config::model;
    use dschat::sim::{simulate_step3, Cluster, Recipe};
    // DDP with a 175B model on one V100 must be None, never a huge number.
    let out = simulate_step3(
        &hf_ddp(),
        &model("opt-175b"),
        &model("opt-350m"),
        &Cluster::single(dschat::sim::v100_32g()),
        &Recipe::default(),
    );
    assert!(out.is_none());
}

#[test]
fn simulator_outputs_always_finite_when_present() {
    use dschat::baselines::all_systems;
    use dschat::config::{model, model_zoo};
    use dschat::sim::{simulate_step3, a100_40g, a100_80g, Cluster, Recipe};
    let critic = model("opt-350m");
    let r = Recipe::default();
    for sys in all_systems() {
        for m in model_zoo().iter().filter(|m| m.name.starts_with("opt-")) {
            for cluster in [
                Cluster::single(a100_40g()),
                Cluster::dgx(a100_80g(), 1),
                Cluster::dgx(a100_80g(), 8),
            ] {
                if let Some(o) = simulate_step3(&sys, m, &critic, &cluster, &r) {
                    assert!(o.gen_secs.is_finite() && o.gen_secs > 0.0, "{} {}", sys.name, m.name);
                    assert!(o.train_secs.is_finite() && o.train_secs > 0.0);
                    assert!(o.pairs_per_sec.is_finite() && o.pairs_per_sec > 0.0);
                    assert!(o.gen_microbatch >= 1 && o.train_microbatch >= 1);
                }
            }
        }
    }
}
