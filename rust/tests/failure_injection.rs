//! Failure injection: every user-facing surface must fail loudly and
//! helpfully, never corrupt state. No artifacts required except where noted.

use dschat::pipeline::checkpoint;
use dschat::runtime::{HostTensor, Manifest};
use dschat::util::json::Json;

fn tmp(name: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join("dschat_failure_tests");
    std::fs::create_dir_all(&d).unwrap();
    d.join(name)
}

// ---------------------------------------------------------------------------
// manifest failures
// ---------------------------------------------------------------------------

#[test]
fn manifest_missing_dir_mentions_make_artifacts() {
    let err = Manifest::load("/no/such/dir").unwrap_err();
    assert!(format!("{err:#}").contains("make artifacts"));
}

#[test]
fn manifest_invalid_json_reports_position() {
    let d = tmp("bad_json");
    std::fs::create_dir_all(&d).unwrap();
    std::fs::write(d.join("manifest.json"), "{\"run\": ").unwrap();
    let err = Manifest::load(&d).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("json error"), "{msg}");
}

#[test]
fn manifest_missing_key_panics_with_key_name() {
    let d = tmp("missing_key");
    std::fs::create_dir_all(&d).unwrap();
    std::fs::write(d.join("manifest.json"), r#"{"run": "x"}"#).unwrap();
    let res = std::panic::catch_unwind(|| Manifest::load(&d));
    // `at()` panics naming the missing key — acceptable loud failure.
    assert!(res.is_err() || res.unwrap().is_err());
}

#[test]
fn manifest_validate_catches_inconsistent_shapes() {
    // seq_len != prompt+gen must be rejected.
    let d = tmp("bad_seq");
    std::fs::create_dir_all(&d).unwrap();
    let text = r#"{
      "run": "bad",
      "config": {
        "batch": 2, "prompt_len": 4, "gen_len": 4, "seq_len": 9,
        "actor": {"name":"a","vocab":16,"d_model":8,"n_layers":1,"n_heads":2,"d_ff":16,"max_seq":8},
        "critic": {"name":"c","vocab":16,"d_model":8,"n_layers":1,"n_heads":2,"d_ff":16,"max_seq":8}
      },
      "actor_params": [], "critic_params": [],
      "actor_opt": [], "critic_opt": [],
      "artifacts": {}
    }"#;
    std::fs::write(d.join("manifest.json"), text).unwrap();
    let m = Manifest::load(&d).unwrap();
    let err = m.validate().unwrap_err();
    assert!(format!("{err}").contains("seq_len"));
}

// ---------------------------------------------------------------------------
// checkpoint failures
// ---------------------------------------------------------------------------

#[test]
fn checkpoint_truncated_file_errors() {
    let path = tmp("trunc.bin");
    checkpoint::save(
        &path,
        &[("w".to_string(), HostTensor::F32(vec![1.0; 100], vec![100]))],
    )
    .unwrap();
    let bytes = std::fs::read(&path).unwrap();
    std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
    assert!(checkpoint::load(&path).is_err());
}

#[test]
fn checkpoint_wrong_magic_errors() {
    let path = tmp("magic.bin");
    std::fs::write(&path, b"NOTACKPTxxxxxxxxxxxxxxxx").unwrap();
    let err = checkpoint::load(&path).unwrap_err();
    assert!(format!("{err}").contains("magic"));
}

#[test]
fn checkpoint_absurd_name_length_rejected() {
    let path = tmp("absurd.bin");
    let mut bytes = b"DSCHKPT1".to_vec();
    bytes.extend((1u32).to_le_bytes()); // one tensor
    bytes.extend((u32::MAX).to_le_bytes()); // name_len = 4 GiB
    std::fs::write(&path, &bytes).unwrap();
    let err = checkpoint::load(&path).unwrap_err();
    assert!(format!("{err}").contains("corrupt"), "{err}");
}

// ---------------------------------------------------------------------------
// tensor / json edges
// ---------------------------------------------------------------------------

#[test]
fn host_tensor_type_confusion_errors() {
    let t = HostTensor::I32(vec![1, 2], vec![2]);
    assert!(t.as_f32().is_err());
    assert!(t.item_f32().is_err());
    let f = HostTensor::F32(vec![1.0, 2.0], vec![2]);
    assert!(f.as_i32().is_err());
}

#[test]
fn json_depth_and_garbage() {
    // Deep nesting parses fine (no recursion blowup at sane depths).
    let deep = format!("{}1{}", "[".repeat(200), "]".repeat(200));
    assert!(Json::parse(&deep).is_ok());
    for garbage in ["", "nul", "{\"a\":}", "[1 2]", "\"\\q\"", "tru"] {
        assert!(Json::parse(garbage).is_err(), "{garbage:?} should fail");
    }
}

// ---------------------------------------------------------------------------
// simulator failure surfaces
// ---------------------------------------------------------------------------

#[test]
fn simulator_returns_oom_not_nonsense() {
    use dschat::baselines::hf_ddp;
    use dschat::config::model;
    use dschat::sim::{simulate_step3, Cluster, Recipe};
    // DDP with a 175B model on one V100 must be None, never a huge number.
    let out = simulate_step3(
        &hf_ddp(),
        &model("opt-175b"),
        &model("opt-350m"),
        &Cluster::single(dschat::sim::v100_32g()),
        &Recipe::default(),
    );
    assert!(out.is_none());
}

// ---------------------------------------------------------------------------
// scheduler-level chaos: the serving recovery contract
// ---------------------------------------------------------------------------

mod chaos {
    use std::collections::HashMap;

    use anyhow::Result;
    use dschat::data::synthetic::Vocab;
    use dschat::rollout::RolloutEngine;
    use dschat::sampling::{HostFullRow, PendingRow, SampleOut, SamplerConfig};
    use dschat::serving::chaos::{ChaosConfig, ChaosEngine};
    use dschat::serving::{
        Admission, AdmitOutcome, DecodeBatch, FaultPolicy, FinishReason, Request, Scheduler,
        SlotEngine,
    };

    const VOCAB: usize = 32;
    const SP: usize = 4;
    const SG: usize = 8;
    const CONTENT: i32 = 9;

    /// Scripted slot engine (the serving tests' convention): a prompt's
    /// first token encodes how many content tokens it emits before EOS, so
    /// a greedy sampler replays the plan deterministically — which is what
    /// lets the chaos golden demand bit-identical recovery.
    struct ScriptEngine {
        n_slots: usize,
        plans: Vec<Option<(Vec<i32>, usize)>>,
        prefills: u64,
    }

    impl ScriptEngine {
        fn new(n_slots: usize) -> Self {
            ScriptEngine {
                n_slots,
                plans: (0..n_slots).map(|_| None).collect(),
                prefills: 0,
            }
        }

        fn logits_for(&self, tok: i32) -> Vec<f32> {
            let mut row = vec![0.0f32; VOCAB];
            row[tok as usize] = 1.0;
            row
        }
    }

    impl SlotEngine for ScriptEngine {
        fn n_slots(&self) -> usize {
            self.n_slots
        }

        fn prompt_len(&self) -> usize {
            SP
        }

        fn max_new_tokens(&self) -> usize {
            SG
        }

        fn prefill_slot(&mut self, slot: usize, adm: &Admission) -> Result<AdmitOutcome> {
            assert!(self.plans[slot].is_none(), "prefill into busy slot {slot}");
            let n = adm.prompt[0] as usize;
            let plan: Vec<i32> = (0..SG + 2)
                .map(|j| if j < n { CONTENT } else { Vocab::EOS })
                .collect();
            let row = PendingRow::Logits(self.logits_for(plan[0]));
            self.plans[slot] = Some((plan, 1));
            self.prefills += 1;
            Ok(AdmitOutcome::cold(row))
        }

        fn decode_slots(&mut self, batch: &DecodeBatch) -> Result<SampleOut> {
            let mut data = vec![0.0f32; self.n_slots * VOCAB];
            for slot in 0..self.n_slots {
                if !batch.active[slot] {
                    continue;
                }
                let (plan, cur) = self.plans[slot].as_mut().expect("active free slot");
                let row = self.logits_for(plan[*cur]);
                *cur += 1;
                data[slot * VOCAB..(slot + 1) * VOCAB].copy_from_slice(&row);
            }
            Ok(SampleOut::Logits { data, vocab: VOCAB })
        }

        fn release_slot(&mut self, slot: usize) -> Result<()> {
            assert!(self.plans[slot].is_some(), "release of free slot {slot}");
            self.plans[slot] = None;
            Ok(())
        }
    }

    fn greedy() -> HostFullRow {
        HostFullRow::new(SamplerConfig { greedy: true, ..Default::default() }, 0)
    }

    /// `prompt[0]` = content tokens the scripted engine emits before EOS.
    fn req(id: u64, eos_after: i32, max_new: usize) -> Request {
        let mut prompt = vec![CONTENT; SP];
        prompt[0] = eos_after;
        Request { id, prompt, max_new, seed: None, prefix_len: 0 }
    }

    #[test]
    fn prefill_fault_requeues_with_backoff_and_completes() {
        // One slot, every 2nd prefill faults: request B's admission fails
        // once, waits out the backoff window, then succeeds — nothing is
        // dropped and the fault is visible in the counters.
        let cfg = ChaosConfig { fault_every_prefill: 2, ..Default::default() };
        let policy = FaultPolicy {
            max_retries: 2,
            backoff_steps: 2,
            deadline_steps: 0,
            quarantine_after: 0,
        };
        let mut sched =
            Scheduler::with_policy(ChaosEngine::new(ScriptEngine::new(1), cfg), policy).unwrap();
        sched.submit(req(1, 1, SG)).unwrap();
        sched.submit(req(2, 1, SG)).unwrap();
        let all = sched.run_until_idle(&mut greedy()).unwrap();
        assert_eq!(all.len(), 2);
        assert!(all.iter().all(|c| c.finish == FinishReason::Eos), "{all:?}");
        assert_eq!(all[0].id, 1);
        assert_eq!(all[1].id, 2);
        // The faulted admission cost B its backoff window in the queue.
        assert!(all[1].queued_steps >= 3, "B queued {} steps", all[1].queued_steps);
        assert_eq!(sched.stats.prefill_faults, 1);
        assert_eq!(sched.stats.requeues, 1);
        assert_eq!(sched.stats.retired_failed, 0);
        assert_eq!(sched.engine.injected.prefill_faults, 1);
        assert_eq!(sched.engine.injected.prefill_calls, 3, "2 admissions + 1 faulted attempt");
    }

    #[test]
    fn transient_chaos_recovery_is_bit_identical() {
        // The key golden: under transient-only faults (prefill and decode),
        // every request's tokens and finish reason are IDENTICAL to the
        // fault-free run — retries replay against pristine engine state —
        // and the scheduler's fault counters match the injector's ground
        // truth exactly.
        let reqs = || {
            vec![
                req(0, 1, SG),
                req(1, 100, SG), // length-capped straggler
                req(2, 3, SG),
                req(3, 2, SG),
                req(4, 100, 4),
                req(5, 1, SG),
            ]
        };
        let run = |sched: &mut Scheduler<ChaosEngine<ScriptEngine>>| {
            for r in reqs() {
                sched.submit(r).unwrap();
            }
            let mut by_id: HashMap<u64, (Vec<i32>, FinishReason)> = HashMap::new();
            for c in sched.run_until_idle(&mut greedy()).unwrap() {
                by_id.insert(c.id, (c.tokens, c.finish));
            }
            by_id
        };
        let mut clean =
            Scheduler::new(ChaosEngine::new(ScriptEngine::new(2), ChaosConfig::default()))
                .unwrap();
        let golden = run(&mut clean);
        assert_eq!(clean.stats.prefill_faults, 0);
        assert_eq!(clean.stats.decode_faults, 0);

        let cfg = ChaosConfig {
            fault_every_prefill: 3,
            fault_every_decode: 3,
            ..Default::default()
        };
        let policy = FaultPolicy {
            max_retries: 10, // transients must never exhaust the budget here
            backoff_steps: 1,
            deadline_steps: 0,
            quarantine_after: 0,
        };
        let mut chaotic =
            Scheduler::with_policy(ChaosEngine::new(ScriptEngine::new(2), cfg), policy).unwrap();
        let recovered = run(&mut chaotic);
        assert_eq!(recovered, golden, "recovery must be bit-identical");
        // The injector actually fired, and the scheduler saw every fault.
        let injected = &chaotic.engine.injected;
        assert!(injected.prefill_faults > 0 && injected.decode_faults > 0);
        assert_eq!(chaotic.stats.prefill_faults, injected.prefill_faults);
        assert_eq!(chaotic.stats.decode_faults, injected.decode_faults);
        assert_eq!(chaotic.stats.decode_retries, injected.decode_faults);
        assert_eq!(chaotic.stats.requeues, injected.prefill_faults);
        assert_eq!(chaotic.stats.retired_failed, 0);
        assert_eq!(chaotic.stats.completed, 6);
    }

    #[test]
    fn deadline_retires_overdue_request_before_sampling() {
        // A never-EOS sequence hits the 3-step residency cap and retires
        // with its partial output; the freed slot then serves the next
        // request normally.
        let policy = FaultPolicy { deadline_steps: 3, ..Default::default() };
        let mut sched = Scheduler::with_policy(ScriptEngine::new(1), policy).unwrap();
        sched.submit(req(1, 100, SG)).unwrap(); // would run to SG
        sched.submit(req(2, 1, SG)).unwrap();
        let all = sched.run_until_idle(&mut greedy()).unwrap();
        assert_eq!(all.len(), 2);
        assert_eq!(all[0].id, 1);
        assert_eq!(all[0].finish, FinishReason::Deadline);
        assert_eq!(all[0].generated, 3, "3 tokens sampled before the deadline tick");
        assert_eq!(all[0].response(), &[CONTENT; 3]);
        assert_eq!(all[1].finish, FinishReason::Eos, "the slot recovered for request 2");
        assert_eq!(sched.stats.retired_deadline, 1);
        assert_eq!(sched.stats.retired_eos, 1);
    }

    #[test]
    fn quarantine_routes_traffic_around_a_broken_slot() {
        // Slot 0 faults every prefill: after 2 consecutive faults it is
        // quarantined and ALL traffic completes through slot 1.
        let cfg = ChaosConfig { broken_slots: vec![0], ..Default::default() };
        let policy = FaultPolicy {
            max_retries: 10,
            backoff_steps: 1,
            deadline_steps: 0,
            quarantine_after: 2,
        };
        let mut sched =
            Scheduler::with_policy(ChaosEngine::new(ScriptEngine::new(2), cfg), policy).unwrap();
        for id in 0..3 {
            sched.submit(req(id, 1, SG)).unwrap();
        }
        let all = sched.run_until_idle(&mut greedy()).unwrap();
        assert_eq!(all.len(), 3);
        for c in &all {
            assert_eq!(c.finish, FinishReason::Eos, "req {}: {:?}", c.id, c.finish);
            assert_eq!(c.slot, 1, "req {} must avoid the broken slot", c.id);
        }
        assert_eq!(sched.n_quarantined(), 1);
        assert_eq!(sched.stats.quarantined, 1);
        assert_eq!(sched.stats.prefill_faults, 2, "quarantine capped the fault count");
        assert_eq!(sched.stats.retired_failed, 0, "nothing burned its retry budget");
    }

    #[test]
    fn permanent_decode_failure_retires_failed_and_scheduler_survives() {
        // Every decode call faults: the retry budget exhausts, every live
        // sequence retires as Failed with the tokens it has — and the
        // scheduler stays serviceable for later submissions instead of
        // wedging.
        let cfg = ChaosConfig { seed: 3, decode_fault_p: 1.0, ..Default::default() };
        let policy = FaultPolicy {
            max_retries: 2,
            backoff_steps: 1,
            deadline_steps: 0,
            quarantine_after: 0,
        };
        let mut sched =
            Scheduler::with_policy(ChaosEngine::new(ScriptEngine::new(2), cfg), policy).unwrap();
        sched.submit(req(1, 100, 4)).unwrap();
        sched.submit(req(2, 100, 4)).unwrap();
        let all = sched.run_until_idle(&mut greedy()).unwrap();
        assert_eq!(all.len(), 2);
        for c in &all {
            assert_eq!(c.finish, FinishReason::Failed { retries: 2 }, "req {}", c.id);
            // The admission's pending row was sampled before the first
            // decode, so each sequence keeps exactly one token.
            assert_eq!(c.generated, 1);
            assert_eq!(c.tokens.len(), SP + 1);
        }
        assert_eq!(sched.stats.retired_failed, 2);
        assert_eq!(sched.stats.decode_faults, 3, "initial call + 2 retries");
        assert_eq!(sched.stats.decode_retries, 2);
        assert!(sched.is_idle());
        // The scheduler is still usable: a later request gets the same
        // honest Failed completion, not an error or a hang.
        sched.submit(req(3, 100, 4)).unwrap();
        let later = sched.run_until_idle(&mut greedy()).unwrap();
        assert_eq!(later.len(), 1);
        assert_eq!(later[0].finish, FinishReason::Failed { retries: 2 });
        assert_eq!(sched.stats.retired_failed, 3);
    }

    #[test]
    fn all_slots_quarantined_fails_loudly() {
        // When every slot is quarantined and work is still queued, the
        // scheduler must refuse to spin forever — a loud error naming the
        // condition, not a silent stall.
        let cfg = ChaosConfig { broken_slots: vec![0], ..Default::default() };
        let policy = FaultPolicy {
            max_retries: 10,
            backoff_steps: 1,
            deadline_steps: 0,
            quarantine_after: 1,
        };
        let mut sched =
            Scheduler::with_policy(ChaosEngine::new(ScriptEngine::new(1), cfg), policy).unwrap();
        sched.submit(req(1, 1, SG)).unwrap();
        // First step quarantines the only slot; the next one must bail.
        sched.step(&mut greedy()).unwrap();
        assert_eq!(sched.n_quarantined(), 1);
        let err = sched.step(&mut greedy()).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("quarantined"), "{msg}");
        assert!(msg.contains("unserviceable"), "{msg}");
    }

    #[test]
    fn mid_rollout_transient_faults_leave_experience_groups_intact() {
        // RLHF experience generation rides the same scheduler: transient
        // decode faults during a rollout must not tear a group — every
        // group flushes full, in order, with tokens identical to the
        // fault-free rollout (greedy over scripted rows).
        let prompts: Vec<Vec<i32>> = [1, 100, 2, 1, 3, 1]
            .iter()
            .map(|&n| {
                let mut p = vec![CONTENT; SP];
                p[0] = n;
                p
            })
            .collect();
        let budgets = vec![SG; 6];
        let run = |cfg: ChaosConfig| -> (Vec<(usize, Vec<(u64, Vec<i32>)>)>, u64) {
            let mut engine = ChaosEngine::new(ScriptEngine::new(2), cfg);
            let mut flushed: Vec<(usize, Vec<(u64, Vec<i32>)>)> = Vec::new();
            let stats = RolloutEngine::new(0)
                .run(&mut engine, &mut greedy(), &prompts, &budgets, 2, |_, g| {
                    flushed.push((
                        g.index,
                        g.completions.iter().map(|c| (c.id, c.tokens.clone())).collect(),
                    ));
                    Ok(())
                })
                .unwrap();
            assert_eq!(stats.decode_faults, engine.injected.decode_faults);
            (flushed, engine.injected.decode_faults)
        };
        let (golden, clean_faults) = run(ChaosConfig::default());
        assert_eq!(clean_faults, 0);
        let (chaotic, faults) =
            run(ChaosConfig { fault_every_decode: 3, ..Default::default() });
        assert!(faults > 0, "the injector must have fired");
        assert_eq!(chaotic, golden, "groups and tokens identical under transient chaos");
        // Static grouping held: group g carries ids [2g, 2g+1].
        for (g, members) in &golden {
            let ids: Vec<u64> = members.iter().map(|(id, _)| *id).collect();
            assert_eq!(ids, vec![*g as u64 * 2, *g as u64 * 2 + 1]);
        }
    }
}

// ---------------------------------------------------------------------------
// paged KV ledger: refcount / free-list invariants under chaos-injected ops
// ---------------------------------------------------------------------------

mod paged_ledger_chaos {
    use dschat::hybrid::kv::PageLedger;
    use dschat::util::rng::Rng;

    const SMAX: usize = 16;
    const PS: usize = 4; // page size
    const MB: usize = SMAX / PS; // blocks per slot
    const SLOTS: usize = 3;
    // 9 allocatable pages: the three 3-page prompts fill the pool exactly,
    // so lazy growth past a page boundary at full occupancy preempts, and
    // orphaned prefixes make LRU eviction the only way back in — all three
    // pressure paths run under the fuzz.
    const PAGES: usize = 2 * MB + 2;

    /// A prompt built from one of a few shared prefixes plus a unique tail,
    /// so admissions hit, miss, and collide in the registry.
    fn prompt(rng: &mut Rng, uniq: i32) -> (Vec<i32>, usize) {
        let family = rng.below(3) as i32;
        let declared = [0, PS, 2 * PS][rng.below(3) as usize];
        let mut p: Vec<i32> = (0..2 * PS as i32).map(|j| family * 100 + j).collect();
        p.push(1000 + uniq);
        (p, declared)
    }

    /// Counters and terminal allocator state from one seeded walk. Two
    /// walks with the same seed must produce IDENTICAL fingerprints: the
    /// LRU clock, eviction order, and preemption points are all
    /// deterministic (no hash-map iteration order anywhere in the ledger).
    #[derive(Debug, Default, PartialEq, Eq)]
    struct WalkStats {
        admitted: u32,
        rejected: u32,
        bogus_releases: u32,
        preemptions: u32,
        advanced_tokens: u64,
        evictions: u64,
        pages_stolen: u64,
        collisions: u64,
        free_pages: usize,
        prefixes: usize,
    }

    /// Seeded random walk over the allocator: admissions (cold and shared),
    /// registrations, lazy-growth advances (stepwise and chunked, each
    /// reserving its rows FIRST — boundary crossings draw pages on demand),
    /// and releases — including *injected bogus releases* (double-free) and
    /// wrong-position advances. Pool exhaustion mid-walk takes the real
    /// recovery paths: LRU eviction while the registry holds entries, then
    /// preemption (free + count) when `reserve_rows` reports the pool dry.
    /// After EVERY op, faulted or not, the full refcount/free-list
    /// consistency check must pass: a rejected op may not leak, double-map,
    /// or strand a page.
    fn walk(seed: u64) -> WalkStats {
        let mut rng = Rng::new(0xfeed + seed);
        let mut ledger = PageLedger::paged(SLOTS, SMAX, PS, PAGES);
        let mut st = WalkStats::default();
        for i in 0..400i32 {
            match rng.below(10) {
                // Admission into a random slot (sometimes busy, sometimes
                // into a dry pool — must error without touching the pool).
                0..=3 => {
                    let slot = rng.below(SLOTS as u32) as usize;
                    let (p, declared) = prompt(&mut rng, i);
                    let busy = ledger.len_of(slot).is_some();
                    match ledger.alloc_shared(slot, &p, declared) {
                        Ok(plan) => {
                            assert!(!busy, "admission into busy slot {slot} succeeded");
                            st.admitted += 1;
                            if plan.prefix_hit {
                                assert_eq!(plan.reused_tokens, declared.min(p.len()));
                            }
                            if rng.chance(0.8) {
                                ledger.register_prefix(slot, declared, &p).unwrap();
                            }
                        }
                        Err(_) => st.rejected += 1,
                    }
                }
                // Stepwise advance over the live slots, each reserving its
                // written row FIRST (the lazy-growth contract: a boundary
                // crossing draws a page). An exhausted reservation preempts
                // the slot — free + count — exactly as the scheduler's
                // `reserve_decode` -> `preempt_slot` path retires it.
                4 => {
                    let mut active = vec![false; SLOTS];
                    let mut pos = vec![0i32; SLOTS];
                    for s in 0..SLOTS {
                        let Some(d) = ledger.depth_of(s) else { continue };
                        if d < SMAX && rng.chance(0.7) {
                            if ledger.reserve_rows(s, 1).unwrap() {
                                active[s] = true;
                                pos[s] = d as i32;
                            } else {
                                ledger.free(s).unwrap();
                                st.preemptions += 1;
                            }
                        }
                    }
                    ledger.advance(&active, &pos).unwrap();
                    st.advanced_tokens += active.iter().filter(|&&a| a).count() as u64;
                }
                // Fused chunk advance on one slot: reserve all n rows up
                // front (possibly crossing several page boundaries at
                // once), then catch the ledger up in one call.
                5 => {
                    let slot = rng.below(SLOTS as u32) as usize;
                    if let Some(d) = ledger.depth_of(slot) {
                        if d < SMAX {
                            let n = (1 + rng.below(PS as u32 + 1) as usize).min(SMAX - d);
                            if ledger.reserve_rows(slot, n).unwrap() {
                                ledger.advance_chunk(slot, d as i32, n).unwrap();
                                st.advanced_tokens += n as u64;
                            } else {
                                ledger.free(slot).unwrap();
                                st.preemptions += 1;
                            }
                        }
                    }
                }
                // Advance at a WRONG position: must be rejected.
                6 => {
                    let slot = rng.below(SLOTS as u32) as usize;
                    if let Some(d) = ledger.depth_of(slot) {
                        let mut active = vec![false; SLOTS];
                        let mut pos = vec![0i32; SLOTS];
                        active[slot] = true;
                        pos[slot] = d as i32 + 1;
                        assert!(ledger.advance(&active, &pos).is_err());
                    }
                }
                // Release a random slot — roughly half the draws hit a
                // slot that is already free (the chaos wrapper's
                // best-effort release after an injected admission
                // fault), which must error and change nothing.
                _ => {
                    let slot = rng.below(SLOTS as u32) as usize;
                    let busy = ledger.len_of(slot).is_some();
                    let res = ledger.free(slot);
                    if busy {
                        res.unwrap();
                    } else {
                        assert!(res.is_err(), "double release of slot {slot} succeeded");
                        st.bogus_releases += 1;
                    }
                }
            }
            ledger
                .check_invariants()
                .unwrap_or_else(|e| panic!("seed {seed} op {i}: {e:#}"));
        }
        // Drain: free every slot; every page is then either free or held
        // only by the registry — and the count closes exactly.
        for s in 0..SLOTS {
            if ledger.len_of(s).is_some() {
                ledger.free(s).unwrap();
            }
        }
        ledger
            .check_invariants()
            .unwrap_or_else(|e| panic!("seed {seed} drain: {e:#}"));
        assert_eq!(ledger.n_active(), 0);
        st.evictions = ledger.evictions();
        st.pages_stolen = ledger.pages_stolen();
        st.collisions = ledger.collisions();
        st.free_pages = ledger.free_pages();
        st.prefixes = ledger.n_prefixes();
        st
    }

    #[test]
    fn random_walk_with_injected_release_faults_never_corrupts_the_ledger() {
        let (mut evictions, mut preemptions) = (0u64, 0u32);
        for seed in 0..6u64 {
            let st = walk(seed);
            assert!(st.admitted > 20, "seed {seed}: only {} admissions", st.admitted);
            assert!(st.rejected > 0, "seed {seed}: exhaustion/busy paths never exercised");
            assert!(st.bogus_releases > 0, "seed {seed}: no injected bogus release fired");
            evictions += st.evictions;
            preemptions += st.preemptions;
        }
        // Across the seeds the walk must have driven the allocator through
        // both pressure paths: LRU steals of orphaned prefixes, and
        // mid-decode preemption on a pool too dry even for eviction.
        assert!(evictions > 0, "no walk ever evicted a prefix under pressure");
        assert!(preemptions > 0, "no walk ever preempted on an exhausted pool");
    }

    /// LRU order, eviction victims, steal counts, and preemption points are
    /// pure functions of the op sequence — replaying a walk must land on an
    /// identical fingerprint, counters and terminal state alike.
    #[test]
    fn same_seed_walks_are_bit_identical() {
        for seed in [0u64, 4] {
            assert_eq!(walk(seed), walk(seed), "seed {seed} diverged between runs");
        }
    }
}

// ---------------------------------------------------------------------------
// oversubscription golden: lazy growth must beat full-window reservation
// ---------------------------------------------------------------------------

mod oversubscription {
    use anyhow::Result;
    use dschat::data::synthetic::Vocab;
    use dschat::hybrid::kv::PageLedger;
    use dschat::sampling::{HostFullRow, PendingRow, SampleOut, SamplerConfig};
    use dschat::serving::{
        Admission, AdmitOutcome, DecodeBatch, FaultPolicy, FinishReason, Request, Scheduler,
        SlotEngine,
    };

    const VOCAB: usize = 32;
    const SMAX: usize = 16;
    const PS: usize = 4;
    const MB: usize = SMAX / PS; // 4 blocks per full window
    const SLOTS: usize = 4;
    const SP: usize = 4; // prompt window: exactly one page
    const SG: usize = SMAX - SP; // full-window generation budget
    const CONTENT: i32 = 9;

    /// The chaos ScriptEngine with a REAL `PageLedger` bolted on: logits
    /// stay scripted (`prompt[0]` = content tokens before EOS, so greedy
    /// replays are bit-identical), while every admission, decode write,
    /// and release flows through the allocator exactly as the hybrid
    /// engine's do — prefill is `alloc_shared` + `register_prefix`, decode
    /// is `reserve_rows` (via `reserve_decode`) then `advance`, release is
    /// `free`. That makes the scheduler's preemption/deferral behavior
    /// testable against real page accounting without artifacts.
    struct PagedScriptEngine {
        ledger: PageLedger,
        plans: Vec<Option<(Vec<i32>, usize)>>,
        /// High-water mark of concurrently live slots.
        peak_live: usize,
    }

    impl PagedScriptEngine {
        /// Physical pool of `SLOTS * MB` pages capped to `pool_pages`
        /// allocatable ones — below `SLOTS * MB` the engine runs
        /// OVERSUBSCRIBED: block tables stay valid device indices, but
        /// admissions and lazy growth compete for fewer pages than the
        /// full per-slot windows would reserve.
        fn new(pool_pages: usize) -> Self {
            let mut ledger = PageLedger::paged(SLOTS, SMAX, PS, SLOTS * MB + 1);
            ledger.limit_pages(pool_pages).unwrap();
            PagedScriptEngine {
                ledger,
                plans: (0..SLOTS).map(|_| None).collect(),
                peak_live: 0,
            }
        }

        fn logits_for(&self, tok: i32) -> Vec<f32> {
            let mut row = vec![0.0f32; VOCAB];
            row[tok as usize] = 1.0;
            row
        }
    }

    impl SlotEngine for PagedScriptEngine {
        fn n_slots(&self) -> usize {
            SLOTS
        }

        fn prompt_len(&self) -> usize {
            SP
        }

        fn max_new_tokens(&self) -> usize {
            SG
        }

        fn paged(&self) -> bool {
            true
        }

        fn prefill_slot(&mut self, slot: usize, adm: &Admission) -> Result<AdmitOutcome> {
            assert!(self.plans[slot].is_none(), "prefill into busy slot {slot}");
            let plan = self.ledger.alloc_shared(slot, adm.prompt, adm.prefix_len)?;
            self.ledger.register_prefix(slot, adm.prefix_len, adm.prompt)?;
            self.ledger.check_invariants()?;
            let n = adm.prompt[0] as usize;
            let script: Vec<i32> = (0..SG + 2)
                .map(|j| if j < n { CONTENT } else { Vocab::EOS })
                .collect();
            let pending = PendingRow::Logits(self.logits_for(script[0]));
            self.plans[slot] = Some((script, 1));
            self.peak_live = self.peak_live.max(self.ledger.n_active());
            Ok(AdmitOutcome {
                pending,
                reused_tokens: plan.reused_tokens,
                prefix_hit: plan.prefix_hit,
            })
        }

        fn decode_slots(&mut self, batch: &DecodeBatch) -> Result<SampleOut> {
            let mut data = vec![0.0f32; SLOTS * VOCAB];
            for slot in 0..SLOTS {
                if !batch.active[slot] {
                    continue;
                }
                let (script, cur) = self.plans[slot].as_mut().expect("active free slot");
                let row = self.logits_for(script[*cur]);
                *cur += 1;
                data[slot * VOCAB..(slot + 1) * VOCAB].copy_from_slice(&row);
            }
            // Write-before-advance: every active row's K/V write landed
            // through a table `reserve_decode` grew before this dispatch —
            // `advance` rejects the step if the scheduler ever skipped it.
            self.ledger.advance(batch.active, batch.pos)?;
            self.ledger.check_invariants()?;
            Ok(SampleOut::Logits { data, vocab: VOCAB })
        }

        fn can_admit(&self, prompt: &[i32], prefix_len: usize) -> bool {
            self.ledger.can_admit(prompt, prefix_len)
        }

        fn reserve_decode(&mut self, slot: usize, n: usize) -> Result<bool> {
            self.ledger.reserve_rows(slot, n)
        }

        fn release_slot(&mut self, slot: usize) -> Result<()> {
            assert!(self.plans[slot].is_some(), "release of free slot {slot}");
            self.plans[slot] = None;
            self.ledger.free(slot)
        }
    }

    fn greedy() -> HostFullRow {
        HostFullRow::new(SamplerConfig { greedy: true, ..Default::default() }, 0)
    }

    fn req(id: u64, eos_after: i32) -> Request {
        let mut prompt = vec![CONTENT; SP];
        prompt[0] = eos_after;
        Request { id, prompt, max_new: SG, seed: None, prefix_len: 0 }
    }

    /// Four full-window runners (their lazy growth saturates the pool)
    /// plus two short finishers, on a pool of `pool_pages`. Returns the
    /// completions sorted by id, plus the scheduler for its counters.
    #[allow(clippy::type_complexity)]
    fn run(
        pool_pages: usize,
    ) -> (Vec<(u64, Vec<i32>, FinishReason)>, Scheduler<PagedScriptEngine>) {
        let policy = FaultPolicy {
            max_retries: 100, // preemption must never exhaust the budget here
            backoff_steps: 1,
            deadline_steps: 0,
            quarantine_after: 0,
        };
        let mut sched =
            Scheduler::with_policy(PagedScriptEngine::new(pool_pages), policy).unwrap();
        for (id, eos_after) in [(1, 100), (2, 100), (3, 100), (4, 100), (5, 3), (6, 5)] {
            sched.submit(req(id, eos_after)).unwrap();
        }
        let mut all = sched.run_until_idle(&mut greedy()).unwrap();
        all.sort_by_key(|c| c.id);
        let outs = all.iter().map(|c| (c.id, c.tokens.clone(), c.finish)).collect();
        (outs, sched)
    }

    #[test]
    fn oversubscribed_pool_overlaps_more_work_and_replays_bit_identically() {
        // Control: the full SLOTS * MB pages — every window fits, nothing
        // can preempt.
        let (golden, control) = run(SLOTS * MB);
        assert_eq!(control.stats.preemptions, 0);
        assert_eq!(control.engine.peak_live, SLOTS, "control must fill every slot");
        assert!(golden
            .iter()
            .all(|(_, _, f)| matches!(f, FinishReason::Eos | FinishReason::Length)));

        // Oversubscribed: 10 of 16 pages (62.5%). Full-window reservation
        // could only run floor(10 / MB) = 2 slots concurrently; lazy
        // growth must overlap more — and pay for it with mid-decode
        // preemptions that requeue and recompute from scratch.
        let capped_pool = 10;
        let (outs, capped) = run(capped_pool);
        assert!(
            capped.engine.peak_live > capped_pool / MB,
            "lazy growth overlapped only {} slots — no better than full-window \
             reservation's {}",
            capped.engine.peak_live,
            capped_pool / MB
        );
        assert!(capped.stats.preemptions > 0, "oversubscription never preempted");
        assert_eq!(
            capped.stats.requeues, capped.stats.preemptions,
            "every preemption requeued (none hit the retry budget)"
        );
        assert_eq!(capped.stats.retired_preempted, 0);
        assert!(
            capped.stats.admission_deferrals > 0,
            "a saturated pool must defer admissions, not burn prefill faults"
        );
        assert_eq!(capped.stats.prefill_faults, 0, "deferral, not faulting");
        // The golden: every request — preempted or not — completes with
        // tokens and finish reason BIT-IDENTICAL to the uncapped run.
        assert_eq!(outs, golden);
    }
}

#[test]
fn simulator_outputs_always_finite_when_present() {
    use dschat::baselines::all_systems;
    use dschat::config::{model, model_zoo};
    use dschat::sim::{simulate_step3, a100_40g, a100_80g, Cluster, Recipe};
    let critic = model("opt-350m");
    let r = Recipe::default();
    for sys in all_systems() {
        for m in model_zoo().iter().filter(|m| m.name.starts_with("opt-")) {
            for cluster in [
                Cluster::single(a100_40g()),
                Cluster::dgx(a100_80g(), 1),
                Cluster::dgx(a100_80g(), 8),
            ] {
                if let Some(o) = simulate_step3(&sys, m, &critic, &cluster, &r) {
                    assert!(o.gen_secs.is_finite() && o.gen_secs > 0.0, "{} {}", sys.name, m.name);
                    assert!(o.train_secs.is_finite() && o.train_secs > 0.0);
                    assert!(o.pairs_per_sec.is_finite() && o.pairs_per_sec > 0.0);
                    assert!(o.gen_microbatch >= 1 && o.train_microbatch >= 1);
                }
            }
        }
    }
}
