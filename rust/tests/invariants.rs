//! Cross-module property tests (no artifacts needed): coordinator, data,
//! sampling, and planner invariants under the in-tree property harness.

use dschat::coordinator::gae;
use dschat::data::synthetic::{Mode, TaskGen, Vocab};
use dschat::data::{Blend, DataSplit, Stage};
use dschat::prop_assert;
use dschat::sampling::{softmax, Sampler, SamplerConfig};
use dschat::util::prop::Prop;
use dschat::util::rng::Rng;

#[test]
fn sampler_top_k_support_never_exceeds_k() {
    Prop::new(64).check("top-k support", |rng| {
        let vocab = 8 + rng.below(120) as usize;
        let k = 1 + rng.below(vocab as u32 - 1) as usize;
        let logits: Vec<f32> = (0..vocab).map(|_| rng.normal() as f32 * 3.0).collect();
        let mut s = Sampler::new(
            SamplerConfig { top_k: k, ..Default::default() },
            rng.next_u64(),
        );
        // Build the allowed set: the k largest logits (ties counted loosely).
        let mut sorted: Vec<f32> = logits.clone();
        sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let cutoff = sorted[k - 1];
        for _ in 0..64 {
            let t = s.sample(&logits, &[]) as usize;
            prop_assert!(
                logits[t] >= cutoff - 1e-6,
                "sampled logit {} below top-{k} cutoff {cutoff}",
                logits[t]
            );
        }
        Ok(())
    });
}

#[test]
fn sampler_top_p_keeps_minimal_mass() {
    Prop::new(64).check("top-p mass", |rng| {
        let vocab = 8 + rng.below(60) as usize;
        let p = 0.2 + 0.6 * rng.f32();
        let logits: Vec<f32> = (0..vocab).map(|_| rng.normal() as f32 * 2.0).collect();
        let probs = softmax(&logits);
        let mut s = Sampler::new(
            SamplerConfig { top_p: p, ..Default::default() },
            rng.next_u64(),
        );
        // The sampled set over many draws must have cumulative prob >= p
        // (it is the smallest prefix reaching p, so adding the sampled
        // tokens' masses must reach p) and exclude nothing from the prefix.
        let mut seen = vec![false; vocab];
        for _ in 0..256 {
            seen[s.sample(&logits, &[]) as usize] = true;
        }
        let mass: f32 = probs
            .iter()
            .zip(&seen)
            .filter(|(_, s)| **s)
            .map(|(p, _)| p)
            .sum();
        // All sampled tokens together can't exceed the p-prefix by much more
        // than one token's mass; and sampling can't reach below-cutoff mass.
        prop_assert!(mass <= 1.0 + 1e-6, "mass {mass}");
        // The most probable token is always in the support.
        let top = probs
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        // (draw enough samples that the top token must appear)
        prop_assert!(seen[top], "top token never sampled");
        Ok(())
    });
}

#[test]
fn gae_zero_rewards_perfect_values_gives_zero_everything() {
    Prop::new(64).check("gae zeros", |rng| {
        let n = 1 + rng.below(20) as usize;
        let rewards = vec![0.0f32; n];
        let values = vec![0.0f32; n + 1];
        let out = gae::gae(&rewards, &values, rng.f32(), rng.f32());
        for (a, r) in out.advantages.iter().zip(&out.returns) {
            prop_assert!(a.abs() < 1e-7 && r.abs() < 1e-7, "nonzero gae");
        }
        Ok(())
    });
}

#[test]
fn shaped_rewards_zero_kl_when_policies_agree() {
    Prop::new(64).check("kl zero", |rng| {
        let n = 1 + rng.below(20) as usize;
        let logp: Vec<f32> = (0..n).map(|_| -rng.f32() * 5.0).collect();
        let r = gae::shaped_rewards(&logp, &logp, 1.0, 0.5, 5.0);
        for (i, x) in r.iter().enumerate() {
            let expect = if i == n - 1 { 1.0 } else { 0.0 };
            prop_assert!((x - expect).abs() < 1e-6, "r[{i}]={x}");
        }
        Ok(())
    });
}

#[test]
fn data_split_partitions_all_ids() {
    Prop::new(32).check("split total", |rng| {
        let split = DataSplit::new(
            rng.f64() + 0.01,
            rng.f64() + 0.01,
            rng.f64() + 0.01,
        );
        for id in 0..2000u64 {
            // assign() must return exactly one stage — trivially true by
            // construction, but fractions must cover [0,1).
            let _ = split.assign(id);
        }
        let f = split.frac(Stage::Sft) + split.frac(Stage::Reward) + split.frac(Stage::Rlhf);
        prop_assert!((f - 1.0).abs() < 1e-12, "fracs sum {f}");
        Ok(())
    });
}

#[test]
fn task_reward_bounded_and_monotone_in_prefix_match() {
    Prop::new(128).check("reward bounds", |rng| {
        let g = TaskGen::new(64, 8, 12);
        let p = g.sample_prompt(rng);
        let good = g.expected_response(&p);
        // Any response scores in [0, 1].
        let junk: Vec<i32> = (0..12).map(|_| rng.range(0, 64) as i32).collect();
        let rj = g.reward(&p, &junk);
        prop_assert!((0.0..=1.0).contains(&rj), "junk reward {rj}");
        // Prefix-correct responses score monotonically with prefix length:
        // positions < k match the rule exactly, positions >= k are filled
        // with a per-position token guaranteed NOT to match.
        let mut prev = -1.0f32;
        for k in 0..=g.resp_len {
            let mut resp = junk.clone();
            resp[..k].copy_from_slice(&good[..k]);
            for (i, x) in resp.iter_mut().enumerate().skip(k).take(g.resp_len - k) {
                *x = if good[i] == Vocab::CONTENT_BASE {
                    Vocab::CONTENT_BASE + 1
                } else {
                    Vocab::CONTENT_BASE
                };
            }
            let r = g.reward(&p, &resp);
            prop_assert!(r + 1e-6 >= prev, "reward fell: {prev} -> {r} at k={k}");
            prev = r;
        }
        Ok(())
    });
}

#[test]
fn blend_only_emits_registered_modes() {
    Prop::new(32).check("blend modes", |rng| {
        let modes = vec![Mode::Repeat, Mode::Count];
        let g = TaskGen::new(64, 8, 8).with_modes(modes.clone());
        let mut blend = Blend::new(vec![(g, 1.0)], DataSplit::new(1.0, 1.0, 1.0));
        let batch = blend.sft_batch(rng, 8);
        for i in 0..8 {
            let m = Mode::from_token(batch.row(i)[1]).unwrap();
            prop_assert!(modes.contains(&m), "unexpected mode {m:?}");
        }
        Ok(())
    });
}

#[test]
fn response_mask_matches_response_len() {
    // The coordinator's mask convention: prediction j scores token j+1.
    Prop::new(64).check("mask convention", |rng| {
        let sp = 4 + rng.below(8) as usize;
        let sg = 4 + rng.below(8) as usize;
        let s = sp + sg;
        let mut seq = vec![10i32; s];
        let eos_at = rng.below(sg as u32) as usize;
        seq[sp + eos_at] = Vocab::EOS;
        let len = dschat::coordinator::PpoTrainer::response_len(&seq, sp);
        prop_assert!(len == eos_at + 1, "len {len} != {}", eos_at + 1);
        Ok(())
    });
}

#[test]
fn rng_streams_are_independent() {
    let mut root = Rng::new(7);
    let mut a = root.fork(1);
    let mut b = root.fork(2);
    let xa: Vec<u64> = (0..32).map(|_| a.next_u64()).collect();
    let xb: Vec<u64> = (0..32).map(|_| b.next_u64()).collect();
    assert_ne!(xa, xb);
}
