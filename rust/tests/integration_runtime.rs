//! Integration: rust runtime × real AOT artifacts (requires `make artifacts`).
//!
//! These tests exercise the full L3→L2→L1 stack: HLO text produced by jax
//! (containing interpret-mode Pallas kernels) compiled and executed through
//! the PJRT CPU client, with numerics checked against values the Python
//! test suite independently verifies.

use std::rc::Rc;

use dschat::runtime::{ArtifactSet, Engine, HostTensor, Manifest};

const DIR: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/tiny");

fn engine() -> Rc<Engine> {
    Rc::new(Engine::cpu().expect("PJRT CPU client"))
}

#[test]
fn manifest_loads_and_validates() {
    let m = Manifest::load(DIR).unwrap();
    m.validate().unwrap();
    assert_eq!(m.run, "tiny");
    assert_eq!(m.actor.vocab, 256);
    assert_eq!(m.seq_len, m.prompt_len + m.gen_len);
    assert!(m.artifacts.len() >= 15, "have {}", m.artifacts.len());
}

#[test]
fn init_actor_is_deterministic_and_seeded() {
    let e = engine();
    let arts = ArtifactSet::load(&e, DIR, &["init_actor"]).unwrap();
    let init = arts.get("init_actor").unwrap();
    let p0 = init.call(&[HostTensor::scalar_i32(0)]).unwrap();
    let p0b = init.call(&[HostTensor::scalar_i32(0)]).unwrap();
    let p1 = init.call(&[HostTensor::scalar_i32(1)]).unwrap();
    assert_eq!(p0.len(), arts.manifest.actor_params.len());
    assert_eq!(p0, p0b, "same seed must give identical params");
    assert_ne!(p0, p1, "different seeds must differ");
    // Shapes match the manifest.
    for (t, spec) in p0.iter().zip(&arts.manifest.actor_params) {
        assert_eq!(t.shape(), spec.shape.as_slice(), "{}", spec.name);
    }
    // LayerNorm gains init to exactly 1.
    let lng_idx = arts
        .manifest
        .actor_params
        .iter()
        .position(|s| s.name == "l0.ln1_g")
        .unwrap();
    assert!(p0[lng_idx].as_f32().unwrap().iter().all(|&x| x == 1.0));
}

#[test]
fn sft_step_reduces_loss_from_rust() {
    let e = engine();
    let arts = ArtifactSet::load(&e, DIR, &["init_actor", "sft_step"]).unwrap();
    let m = &arts.manifest;
    let (b, s) = (m.batch, m.seq_len);

    let mut params = arts
        .get("init_actor")
        .unwrap()
        .call(&[HostTensor::scalar_i32(0)])
        .unwrap();
    let mut opt: Vec<HostTensor> = m
        .actor_opt
        .iter()
        .map(|sp| HostTensor::zeros_f32(&sp.shape))
        .collect();

    // Structured data: next token = token + 3 (mod vocab).
    let mut tokens = vec![0i32; b * s];
    for i in 0..b {
        for j in 0..s {
            tokens[i * s + j] = ((i + 3 * j) % m.actor.vocab) as i32;
        }
    }
    let mask = vec![1.0f32; b * (s - 1)];

    let step = arts.get("sft_step").unwrap();
    let np = params.len();
    let no = opt.len();
    let mut losses = Vec::new();
    for _ in 0..10 {
        let mut inputs = params.clone();
        inputs.extend(opt.clone());
        inputs.push(HostTensor::I32(tokens.clone(), vec![b, s]));
        inputs.push(HostTensor::F32(mask.clone(), vec![b, s - 1]));
        inputs.push(HostTensor::scalar_f32(5e-3));
        let out = step.call(&inputs).unwrap();
        assert_eq!(out.len(), np + no + 1);
        params = out[..np].to_vec();
        opt = out[np..np + no].to_vec();
        losses.push(out[np + no].item_f32().unwrap());
    }
    let first = losses[0];
    let last = *losses.last().unwrap();
    assert!(
        last < first * 0.9,
        "sft loss did not fall: {losses:?}"
    );
    // First loss ≈ log(vocab) for a fresh model.
    assert!((first - (m.actor.vocab as f32).ln()).abs() < 1.0, "{first}");
}

#[test]
fn prefill_then_decode_matches_logprobs_forward() {
    // The generation path (prefill + decode artifacts, Pallas decode
    // attention) must produce the same distribution the training path
    // (logprobs_forward, Pallas flash attention) scores — the hybrid
    // engine's inference/train consistency invariant, checked across the
    // FFI boundary.
    let e = engine();
    let arts =
        ArtifactSet::load(&e, DIR, &["init_actor", "prefill", "decode_step", "logprobs_forward"])
            .unwrap();
    let m = &arts.manifest;
    let (b, sp, s) = (m.batch, m.prompt_len, m.seq_len);
    let params = arts
        .get("init_actor")
        .unwrap()
        .call(&[HostTensor::scalar_i32(7)])
        .unwrap();

    let mut prompt = vec![0i32; b * sp];
    for (i, t) in prompt.iter_mut().enumerate() {
        *t = ((i * 13 + 1) % m.actor.vocab) as i32;
    }

    // Greedy-generate 4 tokens via prefill + decode. Padded-prompt-capable
    // artifacts take a per-row valid-start vector after the prompt (all
    // zeros = exact length); older sets take none.
    let mut inputs = params.clone();
    inputs.push(HostTensor::I32(prompt.clone(), vec![b, sp]));
    if m.padded_prompts {
        inputs.push(HostTensor::I32(vec![0; b], vec![b]));
    }
    let out = arts.get("prefill").unwrap().call(&inputs).unwrap();
    let (mut logits, mut kc, mut vc) = (out[0].clone(), out[1].clone(), out[2].clone());

    let vocab = m.actor.vocab;
    let mut seqs = vec![0i32; b * s];
    for i in 0..b {
        seqs[i * s..i * s + sp].copy_from_slice(&prompt[i * sp..(i + 1) * sp]);
    }
    let n_gen = 4;
    for step in 0..n_gen {
        let l = logits.as_f32().unwrap();
        let mut toks = vec![0i32; b];
        for i in 0..b {
            toks[i] = dschat::sampling::argmax(&l[i * vocab..(i + 1) * vocab]) as i32;
            seqs[i * s + sp + step] = toks[i];
        }
        if step + 1 == n_gen {
            break;
        }
        let mut inputs = params.clone();
        inputs.push(kc);
        inputs.push(vc);
        inputs.push(HostTensor::I32(toks, vec![b]));
        inputs.push(HostTensor::I32(vec![(sp + step) as i32], vec![1]));
        let out = arts.get("decode_step").unwrap().call(&inputs).unwrap();
        logits = out[0].clone();
        kc = out[1].clone();
        vc = out[2].clone();
    }

    // Score with the training path: every generated token must be the
    // argmax continuation (greedy consistency).
    let mut inputs = params.clone();
    inputs.push(HostTensor::I32(seqs.clone(), vec![b, s]));
    let lp = arts.get("logprobs_forward").unwrap().call(&inputs).unwrap();
    let lp = lp[0].as_f32().unwrap();
    // logprob of a greedy token should be the max over the vocab; verify it
    // is at least large (> log(1/vocab) by a wide margin).
    let uniform = -(vocab as f32).ln();
    for i in 0..b {
        for step in 0..n_gen - 1 {
            let j = i * (s - 1) + sp - 1 + step;
            assert!(
                lp[j] > uniform,
                "greedy token logprob {} <= uniform {uniform}",
                lp[j]
            );
        }
    }
}

#[test]
fn rm_forward_scores_depend_on_lens() {
    let e = engine();
    let arts = ArtifactSet::load(&e, DIR, &["init_critic", "rm_forward"]).unwrap();
    let m = &arts.manifest;
    let (b, s) = (m.batch, m.seq_len);
    let params = arts
        .get("init_critic")
        .unwrap()
        .call(&[HostTensor::scalar_i32(3)])
        .unwrap();
    let mut tokens = vec![0i32; b * s];
    for (i, t) in tokens.iter_mut().enumerate() {
        *t = ((i * 7 + 5) % m.critic.vocab) as i32;
    }
    let call = |lens: Vec<i32>| {
        let mut inputs = params.clone();
        inputs.push(HostTensor::I32(tokens.clone(), vec![b, s]));
        inputs.push(HostTensor::I32(lens, vec![b]));
        arts.get("rm_forward").unwrap().call(&inputs).unwrap()[0]
            .as_f32()
            .unwrap()
            .to_vec()
    };
    let r_last = call(vec![(s - 1) as i32; b]);
    let r_mid = call(vec![(s / 2) as i32; b]);
    assert_eq!(r_last.len(), b);
    assert_ne!(r_last, r_mid, "reward must depend on the scored position");
}

#[test]
fn artifact_arity_is_enforced() {
    let e = engine();
    let arts = ArtifactSet::load(&e, DIR, &["init_actor"]).unwrap();
    let err = arts
        .get("init_actor")
        .unwrap()
        .call(&[HostTensor::scalar_i32(0), HostTensor::scalar_i32(1)])
        .unwrap_err();
    assert!(format!("{err:#}").contains("expects 1 inputs"));
}

#[test]
fn call_to_buffers_enforces_arity() {
    let e = engine();
    let arts = ArtifactSet::load(&e, DIR, &["init_actor"]).unwrap();
    let bufs = [
        e.upload(&HostTensor::scalar_i32(0)).unwrap(),
        e.upload(&HostTensor::scalar_i32(1)).unwrap(),
    ];
    let inputs: Vec<&_> = bufs.iter().collect();
    let n = arts.manifest.actor_params.len();
    let err = arts.get("init_actor").unwrap().call_to_buffers(&inputs, n).unwrap_err();
    assert!(format!("{err:#}").contains("expects 1 inputs"));
}

#[test]
fn call_to_buffers_tuple_outputs_stay_per_element() {
    // A multi-output artifact must come back as one device buffer per tuple
    // element (whether the wrapper untupled or the fallback decomposed).
    let e = engine();
    let arts = ArtifactSet::load(&e, DIR, &["init_actor"]).unwrap();
    let seed = e.upload(&HostTensor::scalar_i32(3)).unwrap();
    let n = arts.manifest.actor_params.len();
    let bufs = arts.get("init_actor").unwrap().call_to_buffers(&[&seed], n).unwrap();
    assert_eq!(bufs.len(), n);
    for (buf, spec) in bufs.iter().zip(&arts.manifest.actor_params) {
        let t = e.fetch("test", buf).unwrap();
        assert_eq!(t.shape(), spec.shape.as_slice(), "{}", spec.name);
    }
}

#[test]
fn call_to_buffers_roundtrip_matches_call_buffers() {
    // Equivalence: executing on device and selectively fetching must be
    // bit-identical to the literal path, for both tuple-output (init_actor)
    // and single-output (logprobs_forward) artifacts — and the device
    // outputs of one call must be usable directly as inputs to the next.
    let e = engine();
    let arts = ArtifactSet::load(&e, DIR, &["init_actor", "logprobs_forward"]).unwrap();
    let m = &arts.manifest;
    let init = arts.get("init_actor").unwrap();
    let seed = e.upload(&HostTensor::scalar_i32(5)).unwrap();

    let lits = init.call_buffers(&[&seed]).unwrap();
    let via_literals: Vec<HostTensor> =
        lits.iter().map(|l| HostTensor::from_literal(l).unwrap()).collect();
    let param_bufs = init.call_to_buffers(&[&seed], m.actor_params.len()).unwrap();
    let via_buffers: Vec<HostTensor> =
        param_bufs.iter().map(|b| e.fetch("test", b).unwrap()).collect();
    assert_eq!(via_literals, via_buffers, "bit-identical round trip");

    // Single (non-tuple) output: exactly one device buffer, same numbers.
    let (b, s) = (m.batch, m.seq_len);
    let tokens: Vec<i32> = (0..b * s).map(|i| (i % m.actor.vocab) as i32).collect();
    let tok_buf = e.upload(&HostTensor::I32(tokens, vec![b, s])).unwrap();
    let mut inputs: Vec<&_> = param_bufs.iter().collect();
    inputs.push(&tok_buf);
    let lp = arts.get("logprobs_forward").unwrap();
    let out = lp.call_to_buffers(&inputs, 1).unwrap();
    assert_eq!(out.len(), 1, "single-output artifact yields one buffer");
    let fetched = e.fetch("test", &out[0]).unwrap();
    assert_eq!(fetched.shape(), &[b, s - 1]);
    let lit_out = lp.call_buffers(&inputs).unwrap();
    assert_eq!(fetched, HostTensor::from_literal(&lit_out[0]).unwrap());
}

#[test]
fn exec_stats_count_bytes_moved() {
    let e = engine();
    let arts = ArtifactSet::load(&e, DIR, &["init_actor"]).unwrap();
    e.reset_stats();
    let seed = e.upload(&HostTensor::scalar_i32(0)).unwrap();
    let n = arts.manifest.actor_params.len();
    let bufs = arts.get("init_actor").unwrap().call_to_buffers(&[&seed], n).unwrap();
    let fetched = e.fetch("init_actor", &bufs[0]).unwrap();
    let stats = e.stats();
    assert!(stats["upload"].bytes_uploaded >= 4, "seed scalar upload counted");
    let st = &stats["init_actor"];
    assert!(st.calls >= 1);
    assert!(
        st.bytes_fetched >= 4 * fetched.len() as u64,
        "fetch of {} elements must be counted, saw {}",
        fetched.len(),
        st.bytes_fetched
    );
}
