//! Bench: serve-loop scheduling disciplines under a synthetic Poisson
//! arrival trace — the old fixed-batch policy (drain the queue, pad the
//! artifact batch with repeats, hold every slot for the whole generation)
//! vs iteration-level continuous batching (`dschat::serving`).
//! Requires `make artifacts`. `cargo bench --bench serve_loop [-- --smoke]`.
//!
//! Workload: requests arrive Poisson-distributed at ~80% of the
//! fixed-batch service rate, each with its own generation budget
//! `max_new ∈ [gen_len/4, gen_len]` (heterogeneous response lengths are
//! the continuous-batching motivation). The same trace is replayed
//! against both disciplines; the fixed-batch loop cannot honor per-request
//! budgets (its monolithic generate always runs `gen_len` steps and the
//! result is truncated) nor admit mid-flight — which is precisely the
//! scheduling cost being measured.
//!
//! Emits `BENCH_serve.json` with throughput and p50/p95 latency for BOTH
//! disciplines so the perf trajectory is tracked across PRs, plus host
//! bytes/token for the continuous loop under each sampling backend (host
//! full-row vs the device sampling tail, when the artifacts carry it) and
//! — when the artifacts carry the `padded_prompts` capability — a
//! `continuous_mixed` phase replaying the trace with heterogeneous TRUE
//! prompt lengths through the left-padded admission path, reporting the
//! padded-token overhead fraction alongside tok/s and latency;
//! `scripts/verify.sh` runs the `--smoke` mode. When the artifacts carry
//! the `lazy_kv` capability, a `continuous_oversub` phase replays the
//! prefix-heavy traffic with the page pool capped to ~2/3 of the full
//! per-slot reservation (`limit_kv_pages`) and reports peak occupancy,
//! the LRU prefix-eviction steal rate, and the preemption/requeue
//! counters — asserting the capped run's greedy completions carry
//! exactly the uncapped run's tokens. With `--chaos`, a final
//! phase replays the trace through a fault-injecting engine wrapper (~5%
//! transient faults + slow ticks) and reports goodput under faults, the
//! scheduler's retry/requeue counters, and the p95 latency the recovery
//! machinery adds over the fault-free run.

use std::collections::VecDeque;
use std::rc::Rc;
use std::time::{Duration, Instant};

use dschat::data::synthetic::{Prompt, TaskGen, Vocab};
use dschat::hybrid::HybridEngine;
use dschat::runtime::Engine;
use dschat::sampling::{DeviceCategorical, DeviceTopK, HostFullRow, SamplerConfig, SamplingBackend};
use dschat::serving::chaos::{ChaosConfig, ChaosEngine, ChaosStats};
use dschat::serving::{FaultPolicy, Request, SchedStats, Scheduler};
use dschat::telemetry::{Hist, Telemetry};
use dschat::util::rng::Rng;

/// `BENCH_serve.json` format version — bump when fields change shape, so
/// downstream trajectory tooling can detect the break.
/// v2: `continuous_oversub` phase + `oversub_*` pool-pressure fields.
const SCHEMA_VERSION: u32 = 2;

/// Latency-histogram blocks for one phase, from that phase's private
/// telemetry handle (each phase installs a fresh one, so the percentiles
/// are per-phase, not cumulative).
fn hist_json(tel: &Telemetry) -> String {
    format!(
        ",\n    \"ttft_ms\": {},\n    \"inter_token_ms\": {},\n    \"queue_wait_ms\": {}",
        tel.hist(Hist::Ttft).json_ms_block(),
        tel.hist(Hist::InterToken).json_ms_block(),
        tel.hist(Hist::QueueWait).json_ms_block(),
    )
}

/// The disabled-telemetry hot path must stay free: record N events against
/// a disabled handle and assert the per-call cost is branch-cheap. This is
/// the overhead contract the serving phases rely on when tracing is off.
fn assert_disabled_overhead() -> f64 {
    let tel = Telemetry::disabled();
    let n = 10_000_000u64;
    let t0 = Instant::now();
    for i in 0..n {
        tel.instant(1, "noop", std::hint::black_box(i), 0);
    }
    let ns = t0.elapsed().as_secs_f64() * 1e9 / n as f64;
    assert_eq!(tel.event_count(), 0, "disabled handle recorded events");
    assert!(
        ns < 50.0,
        "disabled-telemetry event cost {ns:.1}ns/call exceeds the 50ns overhead bound \
         — the disabled path must stay a branch on an Option"
    );
    println!("telemetry overhead: disabled path {ns:.2}ns/event (bound 50ns) ✓");
    ns
}

struct PhaseResult {
    name: &'static str,
    completed: usize,
    tokens: u64,
    /// Seconds from trace start to the last completion.
    makespan: f64,
    /// Per-request latency (arrival -> completion), seconds, sorted.
    lat: Vec<f64>,
    /// Host bytes moved (down, up) during the phase, from the byte ledger.
    bytes: (u64, u64),
}

impl PhaseResult {
    fn tok_per_sec(&self) -> f64 {
        self.tokens as f64 / self.makespan.max(1e-9)
    }

    fn pct(&self, q: f64) -> f64 {
        if self.lat.is_empty() {
            return 0.0;
        }
        self.lat[((self.lat.len() - 1) as f64 * q) as usize]
    }

    fn mean(&self) -> f64 {
        self.lat.iter().sum::<f64>() / self.lat.len().max(1) as f64
    }

    fn down_per_tok(&self) -> f64 {
        self.bytes.0 as f64 / self.tokens.max(1) as f64
    }

    fn up_per_tok(&self) -> f64 {
        self.bytes.1 as f64 / self.tokens.max(1) as f64
    }

    fn print(&self) {
        println!(
            "{:<22} {:>4} reqs  {:>6} tok  {:>8.1} tok/s  latency mean {:>7.0}ms  \
             p50 {:>7.0}ms  p95 {:>7.0}ms  host/tok {:>8.0}B down {:>6.0}B up",
            self.name,
            self.completed,
            self.tokens,
            self.tok_per_sec(),
            self.mean() * 1e3,
            self.pct(0.5) * 1e3,
            self.pct(0.95) * 1e3,
            self.down_per_tok(),
            self.up_per_tok(),
        );
    }
}

/// Useful generated tokens of a (possibly truncated) response row: up to
/// and including EOS when emitted, the full budget otherwise.
fn resp_tokens(resp: &[i32]) -> u64 {
    match resp.iter().position(|&t| t == Vocab::EOS) {
        Some(i) => (i + 1) as u64,
        None => resp.len() as u64,
    }
}

fn sleep_until(start: Instant, t: f64) {
    let now = start.elapsed().as_secs_f64();
    if t > now {
        std::thread::sleep(Duration::from_secs_f64(t - now));
    }
}

/// The pre-scheduler serve policy: block for one request, drain the queue
/// up to `b`, pad with repeats, run one monolithic generation, reply to
/// the real rows — every slot held for the full `gen_len` steps.
#[allow(clippy::too_many_arguments)]
fn run_fixed_batch(
    he: &mut HybridEngine,
    prompts: &[Prompt],
    budgets: &[usize],
    arrivals: &[f64],
    b: usize,
    sp: usize,
    s: usize,
    sampler: &mut dyn SamplingBackend,
) -> anyhow::Result<PhaseResult> {
    let n = prompts.len();
    let (down0, up0) = {
        let (up, down) = he.engine.bytes_moved();
        (down, up)
    };
    let start = Instant::now();
    let mut queue: VecDeque<usize> = VecDeque::new();
    let mut next = 0usize;
    let mut lat = Vec::with_capacity(n);
    let mut tokens = 0u64;
    let mut last_done = 0.0f64;
    while lat.len() < n {
        let now = start.elapsed().as_secs_f64();
        while next < n && arrivals[next] <= now {
            queue.push_back(next);
            next += 1;
        }
        if queue.is_empty() {
            sleep_until(start, arrivals[next]);
            continue;
        }
        let take = queue.len().min(b);
        let batch: Vec<usize> = queue.drain(..take).collect();
        let mut flat = Vec::with_capacity(b * sp);
        for i in 0..b {
            let ri = batch[i.min(batch.len() - 1)];
            flat.extend_from_slice(&prompts[ri].tokens);
        }
        let seqs = he.generate(&flat, sampler)?;
        let done_at = start.elapsed().as_secs_f64();
        last_done = done_at;
        for (row, &ri) in batch.iter().enumerate() {
            let resp = &seqs[row * s + sp..(row + 1) * s];
            tokens += resp_tokens(&resp[..budgets[ri]]);
            lat.push(done_at - arrivals[ri]);
        }
    }
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let (up, down) = he.engine.bytes_moved();
    Ok(PhaseResult {
        name: "fixed_batch",
        completed: n,
        tokens,
        makespan: last_done,
        lat,
        bytes: (down - down0, up - up0),
    })
}

/// Iteration-level continuous batching over the same trace: arrivals are
/// submitted as they land, the scheduler admits/retires at decode-step
/// boundaries, and per-request budgets are honored exactly. `prefix_lens`
/// is each request's declared shared-prefix length (all zeros outside the
/// prefix-heavy phase).
#[allow(clippy::too_many_arguments)]
fn run_continuous(
    name: &'static str,
    sched: &mut Scheduler<HybridEngine>,
    prompts: &[Prompt],
    budgets: &[usize],
    arrivals: &[f64],
    prefix_lens: &[usize],
    sampler: &mut dyn SamplingBackend,
) -> anyhow::Result<PhaseResult> {
    let n = prompts.len();
    let (down0, up0) = {
        let (up, down) = sched.engine.engine.bytes_moved();
        (down, up)
    };
    let start = Instant::now();
    let mut next = 0usize;
    let mut lat_by_done = Vec::with_capacity(n);
    let mut tokens = 0u64;
    let mut last_done = 0.0f64;
    while lat_by_done.len() < n {
        let now = start.elapsed().as_secs_f64();
        while next < n && arrivals[next] <= now {
            sched.submit(Request {
                id: next as u64,
                prompt: prompts[next].tokens.clone(),
                max_new: budgets[next],
                seed: None,
                prefix_len: prefix_lens[next],
            })?;
            next += 1;
        }
        if sched.is_idle() {
            sleep_until(start, arrivals[next]);
            continue;
        }
        for c in sched.step(sampler)? {
            let done_at = start.elapsed().as_secs_f64();
            last_done = done_at;
            tokens += c.generated as u64;
            lat_by_done.push(done_at - arrivals[c.id as usize]);
        }
    }
    let mut lat = lat_by_done;
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let (up, down) = sched.engine.engine.bytes_moved();
    Ok(PhaseResult {
        name,
        completed: n,
        tokens,
        makespan: last_done,
        lat,
        bytes: (down - down0, up - up0),
    })
}

/// The continuous loop again, but through a fault-injecting
/// [`ChaosEngine`] wrapper — same trace, same greedy sampling, ~5% of
/// engine calls failing transiently plus jittered slow ticks. Measures
/// goodput and added tail latency while the scheduler retries/requeues;
/// under transient-only faults every request still completes with the
/// fault-free tokens (the recovery path replays against pristine inner
/// state). Separate from [`run_continuous`] because the byte ledger lives
/// one level deeper (`sched.engine.inner.engine`).
fn run_chaos(
    sched: &mut Scheduler<ChaosEngine<HybridEngine>>,
    prompts: &[Prompt],
    budgets: &[usize],
    arrivals: &[f64],
    sampler: &mut dyn SamplingBackend,
) -> anyhow::Result<PhaseResult> {
    let n = prompts.len();
    let (down0, up0) = {
        let (up, down) = sched.engine.inner.engine.bytes_moved();
        (down, up)
    };
    let start = Instant::now();
    let mut next = 0usize;
    let mut lat_by_done = Vec::with_capacity(n);
    let mut tokens = 0u64;
    let mut last_done = 0.0f64;
    while lat_by_done.len() < n {
        let now = start.elapsed().as_secs_f64();
        while next < n && arrivals[next] <= now {
            sched.submit(Request {
                id: next as u64,
                prompt: prompts[next].tokens.clone(),
                max_new: budgets[next],
                seed: None,
                prefix_len: 0,
            })?;
            next += 1;
        }
        if sched.is_idle() {
            sleep_until(start, arrivals[next]);
            continue;
        }
        for c in sched.step(sampler)? {
            let done_at = start.elapsed().as_secs_f64();
            last_done = done_at;
            tokens += c.generated as u64;
            lat_by_done.push(done_at - arrivals[c.id as usize]);
        }
    }
    let mut lat = lat_by_done;
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let (up, down) = sched.engine.inner.engine.bytes_moved();
    Ok(PhaseResult {
        name: "continuous_chaos",
        completed: n,
        tokens,
        makespan: last_done,
        lat,
        bytes: (down - down0, up - up0),
    })
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let with_chaos = std::env::args().any(|a| a == "--chaos");
    let dir = std::env::args()
        .skip(1)
        .find(|a| !a.starts_with("--"))
        .unwrap_or_else(|| "artifacts/tiny".into());
    println!("== serve_loop ({dir}{}) ==", if smoke { ", smoke" } else { "" });
    let overhead_ns = assert_disabled_overhead();
    let engine = Rc::new(Engine::cpu()?);
    let mut he = HybridEngine::init(engine, &dir, 0, false)?;
    let m = he.manifest();
    let (b, sp, sg, s) = (m.batch, m.prompt_len, m.gen_len, m.seq_len);
    let run_name = m.run.clone();
    let task = TaskGen::new(m.actor.vocab, sp, sg);
    let mut rng = Rng::new(7);

    let n_req = if smoke { 2 * b } else { 10 * b };
    let prompts: Vec<Prompt> = (0..n_req).map(|_| task.sample_prompt(&mut rng)).collect();
    let budgets: Vec<usize> =
        (0..n_req).map(|_| rng.range((sg / 4).max(1) as i64, sg as i64 + 1) as usize).collect();

    // Calibrate the fixed-batch service time (one warmup + one measured
    // generation), then lay down Poisson arrivals at ~80% of that rate.
    let mut flat = Vec::with_capacity(b * sp);
    for i in 0..b {
        flat.extend_from_slice(&prompts[i % n_req].tokens);
    }
    let mut sampler = HostFullRow::new(SamplerConfig { greedy: true, ..Default::default() }, 0);
    he.generate(&flat, &mut sampler)?;
    let t0 = Instant::now();
    he.generate(&flat, &mut sampler)?;
    let t_gen = t0.elapsed().as_secs_f64().max(1e-6);
    let rate = 0.8 * b as f64 / t_gen; // requests/s offered
    let mut arrivals = Vec::with_capacity(n_req);
    let mut t = 0.0f64;
    for _ in 0..n_req {
        t += -rng.f64().max(1e-12).ln() / rate;
        arrivals.push(t);
    }
    println!(
        "trace: {n_req} requests, Poisson rate {rate:.2}/s (fixed-batch t_gen {:.0}ms), \
         budgets {}..={} tokens",
        t_gen * 1e3,
        budgets.iter().min().unwrap(),
        budgets.iter().max().unwrap(),
    );

    let greedy = || SamplerConfig { greedy: true, ..Default::default() };
    // Each phase gets a FRESH telemetry handle so its TTFT / inter-token /
    // queue-wait percentiles describe that phase alone (installed after
    // calibration so the warmup generations don't pollute the fixed phase).
    he.set_telemetry(Telemetry::enabled_default());
    let fixed_tel = he.telemetry.clone();
    let fixed = run_fixed_batch(
        &mut he,
        &prompts,
        &budgets,
        &arrivals,
        b,
        sp,
        s,
        &mut HostFullRow::new(greedy(), 0),
    )?;
    fixed.print();

    let sampled_ready = {
        let m = he.manifest();
        m.artifacts.contains_key("decode_slots_sampled")
            && m.artifacts.contains_key("prefill_slot_sampled")
            && m.sample_k > 0
    };
    let sample_k = he.manifest().sample_k;
    let vocab = he.manifest().actor.vocab;
    let padded_ready = he.manifest().padded_prompts;
    let paged_ready = he.manifest().has_paged_serving();
    let page_size = he.manifest().page_size;
    let no_prefix = vec![0usize; n_req];
    let mut sched = Scheduler::new(he)?;
    sched.set_telemetry(Telemetry::enabled_default());
    let host_tel = sched.telemetry().clone();
    let cont = run_continuous(
        "continuous_host",
        &mut sched,
        &prompts,
        &budgets,
        &arrivals,
        &no_prefix,
        &mut HostFullRow::new(greedy(), 0),
    )?;
    cont.print();
    // Snapshot the host phase's scheduler counters before the device phase
    // reuses the scheduler — the cross-PR-tracked JSON fields must describe
    // ONE phase, not the sum of both.
    let st_host = sched.stats.clone();

    // Same trace again under the device sampling tail: identical greedy
    // sequences, O(b) ids fetched per tick instead of [b, vocab] logits.
    let cont_device = if sampled_ready {
        let mut backend = DeviceTopK::new(greedy(), 0, sample_k, vocab)?;
        sched.set_telemetry(Telemetry::enabled_default());
        let tel = sched.telemetry().clone();
        let r = run_continuous(
            "continuous_device",
            &mut sched,
            &prompts,
            &budgets,
            &arrivals,
            &no_prefix,
            &mut backend,
        )?;
        r.print();
        Some((r, tel))
    } else {
        println!("(artifacts lack the `_sampled` family — device-backend phase skipped)");
        None
    };
    // Mixed-length phase: the same arrival discipline with heterogeneous
    // TRUE prompt lengths in [sp/2 (>= structural floor), sp] — genuinely
    // mixed traffic through the left-padded admission path. Pads are never
    // sampled; the phase additionally reports the padded-token overhead
    // (fraction of prefill-written prompt-window entries that were
    // left-padding — the price of riding the fixed AOT shape).
    let cont_mixed = if padded_ready {
        let mut mrng = Rng::new(41);
        let min_len = TaskGen::MIN_PROMPT_LEN.max(sp / 2).min(sp);
        let mixed: Vec<Prompt> = (0..n_req)
            .map(|_| {
                let len = mrng.range(min_len as i64, sp as i64 + 1) as usize;
                task.sample_prompt_len(&mut mrng, len)
            })
            .collect();
        let pads0 = (sched.stats.prompt_tokens, sched.stats.pad_tokens);
        sched.set_telemetry(Telemetry::enabled_default());
        let tel = sched.telemetry().clone();
        let r = run_continuous(
            "continuous_mixed",
            &mut sched,
            &mixed,
            &budgets,
            &arrivals,
            &no_prefix,
            &mut HostFullRow::new(greedy(), 0),
        )?;
        r.print();
        let dprompt = sched.stats.prompt_tokens - pads0.0;
        let dpad = sched.stats.pad_tokens - pads0.1;
        let pad_frac = dpad as f64 / (dprompt + dpad).max(1) as f64;
        println!(
            "continuous_mixed: prompt lengths {min_len}..={sp}, padded-token overhead {:.1}%",
            100.0 * pad_frac
        );
        Some((r, pad_frac, min_len, tel))
    } else {
        println!("(artifacts lack the `padded_prompts` capability — mixed-length phase skipped)");
        None
    };

    // Prefix-heavy phase: the same arrival discipline through the
    // BLOCK-PAGED serving cache, every request carrying the same
    // page-aligned system prompt (+ a unique tail when the geometry leaves
    // room). The first admission computes and registers the prefix; later
    // admissions map its pages — computed tokens fall below admitted
    // tokens and the registry hit rate lands in the JSON.
    let cont_prefix = if paged_ready {
        let share = (sp / page_size) * page_size;
        let mut prng = Rng::new(4242);
        let system: Vec<i32> = task.sample_prompt(&mut prng).tokens[..share.min(sp)].to_vec();
        let prefixed: Vec<Prompt> = (0..n_req)
            .map(|_| {
                let mut p = task.sample_prompt(&mut prng);
                p.tokens[..system.len()].copy_from_slice(&system);
                p
            })
            .collect();
        let prefix_lens = vec![share; n_req];
        let mut phe = sched.into_engine();
        phe.use_paged_serving(true)?;
        let mut psched = Scheduler::new(phe)?;
        psched.set_telemetry(Telemetry::enabled_default());
        let tel = psched.telemetry().clone();
        let r = run_continuous(
            "continuous_prefix",
            &mut psched,
            &prefixed,
            &budgets,
            &arrivals,
            &prefix_lens,
            &mut HostFullRow::new(greedy(), 0),
        )?;
        r.print();
        let pst = psched.stats.clone();
        println!(
            "continuous_prefix: admitted {} tokens, computed {} ({} reused), \
             registry hit rate {:.0}% ({} hits / {} misses)",
            pst.admitted_tokens(),
            pst.computed_tokens(),
            pst.reused_tokens,
            100.0 * pst.cache_hit_rate(),
            pst.prefix_hits,
            pst.prefix_misses,
        );
        // Hand the engine back on the arena layout for the chaos phase.
        let mut bhe = psched.into_engine();
        bhe.use_paged_serving(false)?;
        sched = Scheduler::new(bhe)?;
        Some((r, pst, tel))
    } else {
        println!("(artifacts lack the `paged_kv` capability — prefix-heavy phase skipped)");
        None
    };

    // Fused-chunk phase: the same trace through paged serving with the
    // device counter-RNG categorical backend and N decode steps fused per
    // scheduler dispatch (the largest `decode_chunk{N}` the artifacts
    // carry). Each dispatch samples N tokens per live slot on-device, so
    // decode dispatches per token drop ~N× vs every other phase here.
    let chunk_n = {
        let m = sched.engine.manifest();
        if m.has_device_rng() {
            [8usize, 4, 2].into_iter().find(|&n| m.has_decode_chunk(n))
        } else {
            None
        }
    };
    let cont_chunked = if let Some(ncc) = chunk_n {
        let mut phe = sched.into_engine();
        phe.use_paged_serving(true)?;
        let mut csched = Scheduler::new(phe)?;
        csched.set_decode_chunk(ncc)?;
        csched.set_telemetry(Telemetry::enabled_default());
        let tel = csched.telemetry().clone();
        let mut backend = DeviceCategorical::new(greedy(), sample_k, vocab)?;
        let r = run_continuous(
            "continuous_chunked",
            &mut csched,
            &prompts,
            &budgets,
            &arrivals,
            &no_prefix,
            &mut backend,
        )?;
        r.print();
        assert!(r.tokens > 0, "continuous_chunked phase generated zero tokens — dead bench phase");
        let cst = csched.stats.clone();
        println!(
            "continuous_chunked: N={ncc}, {} decode dispatches vs {} at stepwise host, \
             chunk waste {} tokens",
            cst.decode_calls, st_host.decode_calls, cst.chunk_waste_tokens,
        );
        // Hand the engine back on the arena layout for the chaos phase.
        let mut bhe = csched.into_engine();
        bhe.use_paged_serving(false)?;
        sched = Scheduler::new(bhe)?;
        Some((r, cst, ncc, tel))
    } else {
        println!("(artifacts lack the `device_rng`/`decode_chunkN` capabilities — fused-chunk phase skipped)");
        None
    };

    // Oversubscribed phase: the prefix-heavy traffic again, but with the
    // page allocator capped to ~2/3 of the full per-slot reservation
    // (`limit_kv_pages`, gated on the `lazy_kv` artifact capability).
    // Admissions draw only prompt pages, decode grows tables on demand,
    // registered prefixes are LRU-evicted under pressure, and mid-decode
    // exhaustion preempts + requeues — so the phase reports pool
    // occupancy, the steal rate, and the preemption counters, and its
    // greedy completions must still carry exactly the tokens the uncapped
    // prefix phase produced.
    let lazy_ready = paged_ready && sched.engine.manifest().has_lazy_kv();
    let cont_oversub = if lazy_ready {
        // Regenerate the prefix phase's traffic bit-identically (same RNG
        // seed, same construction) so token counts are comparable.
        let share = (sp / page_size) * page_size;
        let mut prng = Rng::new(4242);
        let system: Vec<i32> = task.sample_prompt(&mut prng).tokens[..share.min(sp)].to_vec();
        let prefixed: Vec<Prompt> = (0..n_req)
            .map(|_| {
                let mut p = task.sample_prompt(&mut prng);
                p.tokens[..system.len()].copy_from_slice(&system);
                p
            })
            .collect();
        let prefix_lens = vec![share; n_req];
        let blocks = s / page_size;
        let full = b * blocks;
        let cap = (full * 2 / 3).max(blocks);
        let mut phe = sched.into_engine();
        phe.use_paged_serving(true)?;
        // Preemption is a scheduling event here, not a failure: a large
        // retry budget guarantees every preempted request requeues until
        // it completes (greedy replay is deterministic, so the tokens
        // still match the uncapped run).
        let policy = FaultPolicy {
            max_retries: 100,
            backoff_steps: 1,
            deadline_steps: 0,
            quarantine_after: 0,
        };
        let mut osched = Scheduler::with_policy(phe, policy)?;
        osched.engine.limit_kv_pages(cap)?;
        osched.set_telemetry(Telemetry::enabled_default());
        let tel = osched.telemetry().clone();
        let r = run_continuous(
            "continuous_oversub",
            &mut osched,
            &prefixed,
            &budgets,
            &arrivals,
            &prefix_lens,
            &mut HostFullRow::new(greedy(), 0),
        )?;
        r.print();
        let ost = osched.stats.clone();
        let occ = osched.engine.kv_occupancy().unwrap_or_default();
        let peak_occupancy = occ.peak_used_pages as f64 / cap.max(1) as f64;
        let steal_rate = occ.pages_stolen as f64 / ost.prefills.max(1) as f64;
        println!(
            "continuous_oversub: pool {cap}/{full} pages ({:.0}%), peak occupancy {:.0}%, \
             {} preemptions ({} requeued, {} retired preempted), {} admission deferrals, \
             {} prefix evictions stealing {} pages ({:.3} pages/admission)",
            100.0 * cap as f64 / full as f64,
            100.0 * peak_occupancy,
            ost.preemptions,
            ost.requeues,
            ost.retired_preempted,
            ost.admission_deferrals,
            occ.prefix_evictions,
            occ.pages_stolen,
            steal_rate,
        );
        if let Some((pr, ..)) = &cont_prefix {
            assert_eq!(
                r.tokens, pr.tokens,
                "oversubscribed greedy completions diverged from the uncapped prefix phase"
            );
        }
        // Hand the engine back on the arena layout for the chaos phase.
        let mut bhe = osched.into_engine();
        bhe.use_paged_serving(false)?;
        sched = Scheduler::new(bhe)?;
        Some((r, ost, occ, cap, full, tel))
    } else {
        println!("(artifacts lack the `lazy_kv` capability — oversubscribed phase skipped)");
        None
    };

    // Chaos phase (`--chaos`): the same trace through a fault-injecting
    // wrapper — ~5% transient prefill/decode faults + 5% slow ticks.
    // Goodput, retry/requeue counts, and the p95 latency the recovery
    // machinery adds over the fault-free continuous_host phase.
    let chaos: Option<(PhaseResult, SchedStats, ChaosStats, Telemetry)> = if with_chaos {
        let he = sched.into_engine();
        let ccfg = ChaosConfig {
            seed: 1234,
            prefill_fault_p: 0.05,
            decode_fault_p: 0.05,
            slow_tick_p: 0.05,
            slow_tick: Duration::from_millis(2),
            ..Default::default()
        };
        let policy = FaultPolicy {
            max_retries: 3,
            backoff_steps: 1,
            deadline_steps: 0,
            quarantine_after: 0,
        };
        let mut csched = Scheduler::with_policy(ChaosEngine::new(he, ccfg), policy)?;
        csched.set_telemetry(Telemetry::enabled_default());
        let tel = csched.telemetry().clone();
        let r = run_chaos(
            &mut csched,
            &prompts,
            &budgets,
            &arrivals,
            &mut HostFullRow::new(greedy(), 0),
        )?;
        r.print();
        let cst = csched.stats.clone();
        let inj = csched.engine.injected.clone();
        println!(
            "chaos: injected {} prefill + {} decode faults, {} slow ticks | scheduler: \
             {} decode retries, {} requeues, {} failed/{} deadline retirements | \
             added p95 {:+.0}ms vs fault-free | tokens match fault-free: {}",
            inj.prefill_faults,
            inj.decode_faults,
            inj.slow_ticks,
            cst.decode_retries,
            cst.requeues,
            cst.retired_failed,
            cst.retired_deadline,
            (r.pct(0.95) - cont.pct(0.95)) * 1e3,
            r.tokens == cont.tokens,
        );
        // The chaos timeline (retry/requeue/fault instants on the queue and
        // slot tracks) is the recovery machinery's inspectable artifact.
        std::fs::write("BENCH_chaos_trace.json", tel.chrome_trace_json())?;
        println!("wrote BENCH_chaos_trace.json ({} events)", tel.event_count());
        Some((r, cst, inj, tel))
    } else {
        None
    };

    let st = &st_host;
    println!(
        "continuous: {} scheduler steps, {} decode calls, {} prefills, slot utilization {:.0}%",
        st.steps,
        st.decode_calls,
        st.prefills,
        100.0 * st.utilization(),
    );
    println!(
        "continuous vs fixed: {:.2}x tok/s, {:.2}x p95 latency",
        cont.tok_per_sec() / fixed.tok_per_sec().max(1e-9),
        cont.pct(0.95) / fixed.pct(0.95).max(1e-9),
    );

    let phase_json = |r: &PhaseResult, tel: &Telemetry| -> String {
        format!(
            "{{\n    \"tok_per_sec\": {:.3},\n    \"mean_ms\": {:.1},\n    \
             \"p50_ms\": {:.1},\n    \"p95_ms\": {:.1},\n    \"makespan_secs\": {:.3},\n    \
             \"tokens\": {},\n    \"host_bytes_fetched_per_token\": {:.1},\n    \
             \"host_bytes_uploaded_per_token\": {:.1}{}\n  }}",
            r.tok_per_sec(),
            r.mean() * 1e3,
            r.pct(0.5) * 1e3,
            r.pct(0.95) * 1e3,
            r.makespan,
            r.tokens,
            r.down_per_tok(),
            r.up_per_tok(),
            hist_json(tel),
        )
    };
    let device_json = match &cont_device {
        Some((r, tel)) => format!(",\n  \"continuous_device\": {}", phase_json(r, tel)),
        None => String::new(),
    };
    let mixed_json = match &cont_mixed {
        Some((r, pad_frac, min_len, tel)) => format!(
            ",\n  \"continuous_mixed\": {},\n  \"mixed_pad_overhead_fraction\": {pad_frac:.4},\n  \
             \"mixed_min_prompt_len\": {min_len}",
            phase_json(r, tel)
        ),
        None => String::new(),
    };
    let prefix_json = match &cont_prefix {
        Some((r, pst, tel)) => format!(
            ",\n  \"continuous_prefix\": {},\n  \"prefix_admitted_tokens\": {},\n  \
             \"prefix_computed_tokens\": {},\n  \"prefix_reused_tokens\": {},\n  \
             \"prefix_cache_hit_rate\": {:.4},\n  \"prefix_hits\": {},\n  \
             \"prefix_misses\": {}",
            phase_json(r, tel),
            pst.admitted_tokens(),
            pst.computed_tokens(),
            pst.reused_tokens,
            pst.cache_hit_rate(),
            pst.prefix_hits,
            pst.prefix_misses,
        ),
        None => String::new(),
    };
    let chunked_json = match &cont_chunked {
        Some((r, cst, ncc, tel)) => format!(
            ",\n  \"continuous_chunked\": {},\n  \"chunk_n\": {ncc},\n  \
             \"chunk_decode_dispatches\": {},\n  \"chunk_dispatches_per_token\": {:.4},\n  \
             \"chunk_waste_tokens\": {}",
            phase_json(r, tel),
            cst.decode_calls,
            cst.decode_calls as f64 / r.tokens.max(1) as f64,
            cst.chunk_waste_tokens,
        ),
        None => String::new(),
    };
    let oversub_json = match &cont_oversub {
        Some((r, ost, occ, cap, full, tel)) => format!(
            ",\n  \"continuous_oversub\": {},\n  \"oversub_pool_pages\": {cap},\n  \
             \"oversub_full_reservation_pages\": {full},\n  \
             \"oversub_pool_fraction\": {:.4},\n  \"oversub_peak_used_pages\": {},\n  \
             \"oversub_peak_occupancy\": {:.4},\n  \"oversub_preemptions\": {},\n  \
             \"oversub_requeues\": {},\n  \"oversub_retired_preempted\": {},\n  \
             \"oversub_admission_deferrals\": {},\n  \"oversub_prefix_evictions\": {},\n  \
             \"oversub_pages_stolen\": {},\n  \"oversub_steal_rate_per_admission\": {:.4}",
            phase_json(r, tel),
            *cap as f64 / (*full).max(1) as f64,
            occ.peak_used_pages,
            occ.peak_used_pages as f64 / (*cap).max(1) as f64,
            ost.preemptions,
            ost.requeues,
            ost.retired_preempted,
            ost.admission_deferrals,
            occ.prefix_evictions,
            occ.pages_stolen,
            occ.pages_stolen as f64 / ost.prefills.max(1) as f64,
        ),
        None => String::new(),
    };
    let chaos_json = match &chaos {
        Some((r, cst, inj, tel)) => format!(
            ",\n  \"chaos\": {},\n  \"chaos_injected_prefill_faults\": {},\n  \
             \"chaos_injected_decode_faults\": {},\n  \"chaos_injected_slow_ticks\": {},\n  \
             \"chaos_decode_retries\": {},\n  \"chaos_requeues\": {},\n  \
             \"chaos_failed_requests\": {},\n  \"chaos_added_p95_ms\": {:.1},\n  \
             \"chaos_tokens_match_fault_free\": {}",
            phase_json(r, tel),
            inj.prefill_faults,
            inj.decode_faults,
            inj.slow_ticks,
            cst.decode_retries,
            cst.requeues,
            cst.retired_failed + cst.retired_deadline,
            (r.pct(0.95) - cont.pct(0.95)) * 1e3,
            r.tokens == cont.tokens,
        ),
        None => String::new(),
    };
    let json = format!(
        "{{\n  \"bench\": \"serve_loop\",\n  \"schema_version\": {SCHEMA_VERSION},\n  \
         \"run\": \"{run_name}\",\n  \"smoke\": {smoke},\n  \
         \"n_requests\": {n_req},\n  \"arrival_rate_per_s\": {rate:.3},\n  \
         \"fixed_batch_t_gen_secs\": {t_gen:.6},\n  \"sample_k\": {sample_k},\n  \
         \"telemetry_overhead_ns_per_event_disabled\": {overhead_ns:.2},\n  \
         \"fixed_batch\": {},\n  \"continuous\": {},\n  \
         \"slot_utilization\": {:.4},\n  \"decode_calls\": {}{}{}{}{}{}{}\n  ,\n  \
         \"speedup_tok_per_sec\": {:.3},\n  \"p95_latency_ratio\": {:.3}\n}}\n",
        phase_json(&fixed, &fixed_tel),
        phase_json(&cont, &host_tel),
        st.utilization(),
        st.decode_calls,
        device_json,
        mixed_json,
        prefix_json,
        chunked_json,
        oversub_json,
        chaos_json,
        cont.tok_per_sec() / fixed.tok_per_sec().max(1e-9),
        cont.pct(0.95) / fixed.pct(0.95).max(1e-9),
    );
    std::fs::write("BENCH_serve.json", &json)?;
    println!("wrote BENCH_serve.json");
    Ok(())
}
