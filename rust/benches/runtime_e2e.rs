//! Bench: the real artifact hot paths end-to-end on the CPU PJRT backend —
//! decode step (the generation hot loop), full-batch forwards, SFT/PPO train
//! steps, and the generation-vs-naive Figure-5 analogue.
//! Requires `make artifacts`. `cargo bench --bench runtime_e2e`.

use std::rc::Rc;
use std::time::Duration;

use dschat::data::synthetic::TaskGen;
use dschat::data::{Blend, DataSplit};
use dschat::examples_support::naive_generate;
use dschat::hybrid::HybridEngine;
use dschat::runtime::Engine;
use dschat::sampling::{Sampler, SamplerConfig};
use dschat::util::bench::Bench;
use dschat::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    // cargo bench passes `--bench`; skip flags when looking for a dir arg.
    let dir = std::env::args()
        .skip(1)
        .find(|a| !a.starts_with("--"))
        .unwrap_or_else(|| "artifacts/tiny".into());
    println!("== runtime e2e ({dir}) ==");
    let engine = Rc::new(Engine::cpu()?);
    let mut he = HybridEngine::init(engine, &dir, 0, true)?;
    let m = he.manifest();
    let (bsz, sp, sg) = (m.batch, m.prompt_len, m.gen_len);
    let task = TaskGen::new(m.actor.vocab, sp, sg);
    let mut blend = Blend::new(vec![(task.clone(), 1.0)], DataSplit::new(2.0, 4.0, 4.0));
    let mut rng = Rng::new(0);
    let b = Bench { budget: Duration::from_secs(3), ..Default::default() };

    // Generation (hybrid path) — tokens/sec is the paper's generation-phase
    // throughput metric.
    let mut flat = Vec::with_capacity(bsz * sp);
    for _ in 0..bsz {
        flat.extend_from_slice(&task.sample_prompt(&mut rng).tokens);
    }
    let mut sampler = Sampler::new(SamplerConfig { greedy: true, ..Default::default() }, 0);
    b.run("generate_hybrid_kv_cache", || {
        std::hint::black_box(he.generate(&flat, &mut sampler).unwrap());
    })
    .print(Some(((bsz * sg) as f64, "tokens")));

    // Naive baseline (full recompute per token) — the Figure-5 comparison.
    b.run("generate_naive_recompute", || {
        std::hint::black_box(naive_generate(&mut he, &flat, &mut sampler).unwrap());
    })
    .print(Some(((bsz * sg) as f64, "tokens")));

    // Experience scoring forwards.
    let batch = blend.sft_batch(&mut rng, bsz);
    b.run("logprobs_forward", || {
        std::hint::black_box(he.actor_logprobs(&batch.tokens).unwrap());
    })
    .print(Some(((bsz * (sp + sg)) as f64, "tokens")));

    // Train steps.
    b.run("sft_step", || {
        std::hint::black_box(he.sft_step(&batch, 1e-3).unwrap());
    })
    .print(Some(((bsz * (sp + sg)) as f64, "tokens")));

    let pb = blend.pair_batch(&mut rng, bsz);
    b.run("rm_step", || {
        std::hint::black_box(he.rm_step(&pb, 1e-3).unwrap());
    })
    .print(Some(((2 * bsz * (sp + sg)) as f64, "tokens")));

    let s = sp + sg;
    let w = s - 1;
    let old_logp = vec![-1.0f32; bsz * w];
    let adv = vec![0.1f32; bsz * w];
    let mask = vec![1.0f32; bsz * w];
    b.run("ppo_actor_step", || {
        std::hint::black_box(
            he.ppo_actor_step(&batch.tokens, &old_logp, &adv, &mask, &batch.tokens, 0.2, 0.2, 1e-4)
                .unwrap(),
        );
    })
    .print(Some(((bsz * s) as f64, "tokens")));

    b.run("ema_update", || {
        he.ema_update(0.992).unwrap();
    })
    .print(None);

    // Executor overhead accounting (upload/exec/fetch split).
    println!("\n-- engine stats (cumulative) --");
    for (name, st) in he.engine.stats() {
        println!(
            "{name:<22} calls {:>6}  exec {:>9}  fetch {:>9}  upload {:>9}",
            st.calls,
            dschat::util::fmt_duration(st.exec_secs),
            dschat::util::fmt_duration(st.fetch_secs),
            dschat::util::fmt_duration(st.upload_secs),
        );
    }
    Ok(())
}
