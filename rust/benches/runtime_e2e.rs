//! Bench: the real artifact hot paths end-to-end on the CPU PJRT backend —
//! decode step (the generation hot loop), full-batch forwards, SFT/PPO train
//! steps, and the generation-vs-naive Figure-5 analogue.
//! Requires `make artifacts`. `cargo bench --bench runtime_e2e`.
//!
//! Pass `--smoke` for a fast CI-friendly run (used by `scripts/verify.sh`).
//! The decode-loop section measures generation tokens/sec and host bytes
//! moved per token and writes `BENCH_decode.json` so the perf trajectory is
//! tracked across PRs; with the zero-copy decode path, bytes/token must be
//! O(b·vocab) — independent of the KV-cache size.

use std::rc::Rc;
use std::time::Duration;

use dschat::data::synthetic::TaskGen;
use dschat::data::{Blend, DataSplit};
use dschat::examples_support::naive_generate;
use dschat::hybrid::{HybridEngine, KvCache};
use dschat::runtime::Engine;
use dschat::sampling::{Sampler, SamplerConfig};
use dschat::util::bench::Bench;
use dschat::util::rng::Rng;
use dschat::util::{fmt_bytes, fmt_duration};

fn main() -> anyhow::Result<()> {
    // cargo bench passes `--bench`; skip flags when looking for a dir arg.
    let smoke = std::env::args().any(|a| a == "--smoke");
    let dir = std::env::args()
        .skip(1)
        .find(|a| !a.starts_with("--"))
        .unwrap_or_else(|| "artifacts/tiny".into());
    println!("== runtime e2e ({dir}{}) ==", if smoke { ", smoke" } else { "" });
    let engine = Rc::new(Engine::cpu()?);
    let mut he = HybridEngine::init(engine, &dir, 0, true)?;
    let m = he.manifest();
    let (bsz, sp, sg) = (m.batch, m.prompt_len, m.gen_len);
    let vocab = m.actor.vocab;
    let kv_bytes = KvCache::bytes_for(m);
    let run_name = m.run.clone();
    let task = TaskGen::new(m.actor.vocab, sp, sg);
    let mut blend = Blend::new(vec![(task.clone(), 1.0)], DataSplit::new(2.0, 4.0, 4.0));
    let mut rng = Rng::new(0);
    let b = if smoke {
        Bench::quick()
    } else {
        Bench { budget: Duration::from_secs(3), ..Default::default() }
    };

    // Generation (hybrid path) — tokens/sec is the paper's generation-phase
    // throughput metric.
    let mut flat = Vec::with_capacity(bsz * sp);
    for _ in 0..bsz {
        flat.extend_from_slice(&task.sample_prompt(&mut rng).tokens);
    }
    let mut sampler = Sampler::new(SamplerConfig { greedy: true, ..Default::default() }, 0);
    b.run("generate_hybrid_kv_cache", || {
        std::hint::black_box(he.generate(&flat, &mut sampler).unwrap());
    })
    .print(Some(((bsz * sg) as f64, "tokens")));

    // Naive baseline (full recompute per token) — the Figure-5 comparison.
    b.run("generate_naive_recompute", || {
        std::hint::black_box(naive_generate(&mut he, &flat, &mut sampler).unwrap());
    })
    .print(Some(((bsz * sg) as f64, "tokens")));

    // Experience scoring forwards.
    let batch = blend.sft_batch(&mut rng, bsz);
    b.run("logprobs_forward", || {
        std::hint::black_box(he.actor_logprobs(&batch.tokens).unwrap());
    })
    .print(Some(((bsz * (sp + sg)) as f64, "tokens")));

    // Train steps.
    b.run("sft_step", || {
        std::hint::black_box(he.sft_step(&batch, 1e-3).unwrap());
    })
    .print(Some(((bsz * (sp + sg)) as f64, "tokens")));

    let pb = blend.pair_batch(&mut rng, bsz);
    b.run("rm_step", || {
        std::hint::black_box(he.rm_step(&pb, 1e-3).unwrap());
    })
    .print(Some(((2 * bsz * (sp + sg)) as f64, "tokens")));

    let s = sp + sg;
    let w = s - 1;
    let old_logp = vec![-1.0f32; bsz * w];
    let adv = vec![0.1f32; bsz * w];
    let mask = vec![1.0f32; bsz * w];
    b.run("ppo_actor_step", || {
        std::hint::black_box(
            he.ppo_actor_step(&batch.tokens, &old_logp, &adv, &mask, &batch.tokens, 0.2, 0.2, 1e-4)
                .unwrap(),
        );
    })
    .print(Some(((bsz * s) as f64, "tokens")));

    b.run("ema_update", || {
        he.ema_update(0.992).unwrap();
    })
    .print(None);

    // Executor overhead accounting (upload/exec/fetch split + bytes moved).
    println!("\n-- engine stats (cumulative) --");
    for (name, st) in he.engine.stats() {
        println!(
            "{name:<22} calls {:>6}  exec {:>9}  fetch {:>9} ({:>9})  upload {:>9} ({:>9}){}",
            st.calls,
            fmt_duration(st.exec_secs),
            fmt_duration(st.fetch_secs),
            fmt_bytes(st.bytes_fetched as f64),
            fmt_duration(st.upload_secs),
            fmt_bytes(st.bytes_uploaded as f64),
            if st.fallback_untuples > 0 {
                format!("  [{} fused-tuple fallbacks]", st.fallback_untuples)
            } else {
                String::new()
            },
        );
    }

    // ------------------------------------------------------------------
    // decode_loop: generation throughput + host traffic per token, from a
    // clean ledger. Emits BENCH_decode.json for the perf trajectory.
    // ------------------------------------------------------------------
    he.engine.reset_stats();
    let tok0 = he.stats.gen_tokens;
    let iters = if smoke { 2 } else { 8 };
    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        std::hint::black_box(he.generate(&flat, &mut sampler)?);
    }
    let secs = t0.elapsed().as_secs_f64();
    let tokens = (he.stats.gen_tokens - tok0).max(1);
    let (up, down) = he.engine.bytes_moved();
    let fallbacks = he.engine.fallback_untuples();
    let tok_per_sec = tokens as f64 / secs;
    let down_per_tok = down as f64 / tokens as f64;
    let up_per_tok = up as f64 / tokens as f64;
    let logits_row_bytes = bsz * vocab * 4;
    println!("\n-- decode_loop ({iters} generates, {tokens} tokens) --");
    println!(
        "{tok_per_sec:>10.1} tokens/s  |  host bytes/token: {} down, {} up",
        fmt_bytes(down_per_tok),
        fmt_bytes(up_per_tok),
    );
    println!(
        "reference: logits row [b,vocab] = {}  |  full KV cache = {}  |  fused-tuple fallbacks {}",
        fmt_bytes(logits_row_bytes as f64),
        fmt_bytes(kv_bytes as f64),
        fallbacks,
    );
    let json = format!(
        "{{\n  \"bench\": \"decode_loop\",\n  \"run\": \"{run_name}\",\n  \"smoke\": {smoke},\n  \
         \"iters\": {iters},\n  \"tokens\": {tokens},\n  \"secs\": {secs:.6},\n  \
         \"tok_per_sec\": {tok_per_sec:.3},\n  \"host_bytes_fetched\": {down},\n  \
         \"host_bytes_uploaded\": {up},\n  \"host_bytes_fetched_per_token\": {down_per_tok:.1},\n  \
         \"host_bytes_uploaded_per_token\": {up_per_tok:.1},\n  \
         \"logits_row_bytes\": {logits_row_bytes},\n  \"kv_cache_bytes\": {kv_bytes},\n  \
         \"fallback_untuples\": {fallbacks}\n}}\n"
    );
    std::fs::write("BENCH_decode.json", &json)?;
    println!("wrote BENCH_decode.json");
    Ok(())
}
