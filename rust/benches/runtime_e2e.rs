//! Bench: the real artifact hot paths end-to-end on the CPU PJRT backend —
//! decode step (the generation hot loop), full-batch forwards, SFT/PPO train
//! steps, and the generation-vs-naive Figure-5 analogue.
//! Requires `make artifacts`. `cargo bench --bench runtime_e2e`.
//!
//! Pass `--smoke` for a fast CI-friendly run (used by `scripts/verify.sh`).
//! The decode-loop section measures generation tokens/sec and host bytes
//! moved per token FOR EACH SAMPLING BACKEND and writes `BENCH_decode.json`
//! so the perf trajectory is tracked across PRs. Contract: host full-row is
//! O(b·vocab) fetched per token, device greedy O(b) (token ids only), and
//! device top-k O(b·k) — independent of both vocab and KV-cache size.
//! A PPO section additionally pins that staging the experience batch once
//! per batch (instead of re-uploading per epoch) shrinks uploaded bytes.

use std::rc::Rc;
use std::time::Duration;

use dschat::data::synthetic::TaskGen;
use dschat::data::{Blend, DataSplit};
use dschat::examples_support::{
    mixed_prompts, naive_generate, rollout_continuous, rollout_continuous_chunked,
    rollout_fixed_baseline,
};
use dschat::hybrid::{HybridEngine, KvCache};
use dschat::runtime::Engine;
use dschat::sampling::{DeviceCategorical, DeviceTopK, HostFullRow, SamplerConfig, SamplingBackend};
use dschat::util::bench::Bench;
use dschat::util::rng::Rng;
use dschat::util::{fmt_bytes, fmt_duration};

struct BackendRun {
    name: &'static str,
    tokens: u64,
    secs: f64,
    down: u64,
    up: u64,
    fallbacks: u64,
}

impl BackendRun {
    fn tok_per_sec(&self) -> f64 {
        self.tokens as f64 / self.secs.max(1e-9)
    }

    fn down_per_tok(&self) -> f64 {
        self.down as f64 / self.tokens.max(1) as f64
    }

    fn up_per_tok(&self) -> f64 {
        self.up as f64 / self.tokens.max(1) as f64
    }

    fn json(&self) -> String {
        format!(
            "{{\n      \"tokens\": {},\n      \"secs\": {:.6},\n      \
             \"tok_per_sec\": {:.3},\n      \"host_bytes_fetched\": {},\n      \
             \"host_bytes_uploaded\": {},\n      \
             \"host_bytes_fetched_per_token\": {:.1},\n      \
             \"host_bytes_uploaded_per_token\": {:.1},\n      \
             \"fallback_untuples\": {}\n    }}",
            self.tokens,
            self.secs,
            self.tok_per_sec(),
            self.down,
            self.up,
            self.down_per_tok(),
            self.up_per_tok(),
            self.fallbacks,
        )
    }
}

fn main() -> anyhow::Result<()> {
    // cargo bench passes `--bench`; skip flags when looking for a dir arg.
    let smoke = std::env::args().any(|a| a == "--smoke");
    let dir = std::env::args()
        .skip(1)
        .find(|a| !a.starts_with("--"))
        .unwrap_or_else(|| "artifacts/tiny".into());
    println!("== runtime e2e ({dir}{}) ==", if smoke { ", smoke" } else { "" });
    let engine = Rc::new(Engine::cpu()?);
    let mut he = HybridEngine::init(engine, &dir, 0, true)?;
    let m = he.manifest();
    let (bsz, sp, sg) = (m.batch, m.prompt_len, m.gen_len);
    let vocab = m.actor.vocab;
    let sample_k = m.sample_k;
    let kv_bytes = KvCache::bytes_for(m);
    let run_name = m.run.clone();
    let sampled_ready = m.artifacts.contains_key("decode_step_sampled") && sample_k > 0;
    let task = TaskGen::new(m.actor.vocab, sp, sg);
    let mut blend = Blend::new(vec![(task.clone(), 1.0)], DataSplit::new(2.0, 4.0, 4.0));
    let mut rng = Rng::new(0);
    let b = if smoke {
        Bench::quick()
    } else {
        Bench { budget: Duration::from_secs(3), ..Default::default() }
    };
    let greedy = || SamplerConfig { greedy: true, ..Default::default() };

    // Generation (hybrid path) — tokens/sec is the paper's generation-phase
    // throughput metric.
    let mut flat = Vec::with_capacity(bsz * sp);
    for _ in 0..bsz {
        flat.extend_from_slice(&task.sample_prompt(&mut rng).tokens);
    }
    let mut sampler = HostFullRow::new(greedy(), 0);
    b.run("generate_hybrid_kv_cache", || {
        std::hint::black_box(he.generate(&flat, &mut sampler).unwrap());
    })
    .print(Some(((bsz * sg) as f64, "tokens")));

    // Naive baseline (full recompute per token) — the Figure-5 comparison.
    b.run("generate_naive_recompute", || {
        std::hint::black_box(naive_generate(&mut he, &flat, &mut sampler).unwrap());
    })
    .print(Some(((bsz * sg) as f64, "tokens")));

    // Experience scoring forwards.
    let batch = blend.sft_batch(&mut rng, bsz);
    b.run("logprobs_forward", || {
        std::hint::black_box(he.actor_logprobs(&batch.tokens).unwrap());
    })
    .print(Some(((bsz * (sp + sg)) as f64, "tokens")));

    // Train steps.
    b.run("sft_step", || {
        std::hint::black_box(he.sft_step(&batch, 1e-3).unwrap());
    })
    .print(Some(((bsz * (sp + sg)) as f64, "tokens")));

    let pb = blend.pair_batch(&mut rng, bsz);
    b.run("rm_step", || {
        std::hint::black_box(he.rm_step(&pb, 1e-3).unwrap());
    })
    .print(Some(((2 * bsz * (sp + sg)) as f64, "tokens")));

    let s = sp + sg;
    let w = s - 1;
    let old_logp = vec![-1.0f32; bsz * w];
    let adv = vec![0.1f32; bsz * w];
    let mask = vec![1.0f32; bsz * w];
    b.run("ppo_actor_step", || {
        std::hint::black_box(
            he.ppo_actor_step(&batch.tokens, &old_logp, &adv, &mask, &batch.tokens, 0.2, 0.2, 1e-4)
                .unwrap(),
        );
    })
    .print(Some(((bsz * s) as f64, "tokens")));

    b.run("ema_update", || {
        he.ema_update(0.992).unwrap();
    })
    .print(None);

    // ------------------------------------------------------------------
    // Shared upload for PPO epochs: stage the experience batch once and
    // re-feed it; the bytes-uploaded counter must drop vs per-epoch
    // re-upload (the ROADMAP item this section pins).
    // ------------------------------------------------------------------
    let returns = vec![0.2f32; bsz * w];
    let old_values = vec![0.15f32; bsz * w];
    let epochs = 2;
    he.engine.reset_stats();
    for _ in 0..epochs {
        he.ppo_actor_step(&batch.tokens, &old_logp, &adv, &mask, &batch.tokens, 0.2, 0.0, 1e-4)?;
        he.ppo_critic_step(&batch.tokens, &returns, &old_values, &mask, 0.2, 5e-4)?;
    }
    let (legacy_up, _) = he.engine.bytes_moved();
    he.engine.reset_stats();
    let staged =
        he.stage_experience(&batch.tokens, &old_logp, &adv, &returns, &old_values, &mask)?;
    for _ in 0..epochs {
        he.ppo_actor_step_staged(&staged, &batch.tokens, 0.2, 0.0, 1e-4)?;
        he.ppo_critic_step_staged(&staged, 0.2, 5e-4)?;
    }
    let (staged_up, _) = he.engine.bytes_moved();
    println!(
        "\n-- ppo epoch uploads ({epochs} epochs) --\n\
         per-epoch re-upload: {}  |  staged once: {}  ({:.2}x less)",
        fmt_bytes(legacy_up as f64),
        fmt_bytes(staged_up as f64),
        legacy_up as f64 / staged_up.max(1) as f64,
    );
    assert!(
        staged_up < legacy_up,
        "staging the experience batch must cut uploaded bytes ({staged_up} vs {legacy_up})"
    );

    // Executor overhead accounting (upload/exec/fetch split + bytes moved).
    println!("\n-- engine stats (cumulative since ppo-upload section) --");
    for (name, st) in he.engine.stats() {
        println!(
            "{name:<22} calls {:>6}  exec {:>9}  fetch {:>9} ({:>9})  upload {:>9} ({:>9}){}",
            st.calls,
            fmt_duration(st.exec_secs),
            fmt_duration(st.fetch_secs),
            fmt_bytes(st.bytes_fetched as f64),
            fmt_duration(st.upload_secs),
            fmt_bytes(st.bytes_uploaded as f64),
            if st.fallback_untuples > 0 {
                format!("  [{} fused-tuple fallbacks]", st.fallback_untuples)
            } else {
                String::new()
            },
        );
    }

    // ------------------------------------------------------------------
    // decode_loop: generation throughput + host traffic per token, per
    // sampling backend, each from a clean ledger. Emits BENCH_decode.json
    // for the perf trajectory. Greedy device sampling must be O(b) bytes
    // per token (ids only); stochastic device sampling O(b·k).
    // ------------------------------------------------------------------
    let iters = if smoke { 2 } else { 8 };
    let mut runs: Vec<BackendRun> = Vec::new();
    let backend_list: Vec<(&'static str, Box<dyn SamplingBackend>)> = {
        let mut v: Vec<(&'static str, Box<dyn SamplingBackend>)> =
            vec![("host_full_row", Box::new(HostFullRow::new(greedy(), 0)))];
        if sampled_ready {
            v.push((
                "device_greedy",
                Box::new(DeviceTopK::new(greedy(), 0, sample_k, vocab)?),
            ));
            v.push((
                "device_topk_stochastic",
                Box::new(DeviceTopK::new(
                    SamplerConfig { temperature: 0.9, top_p: 0.95, ..Default::default() },
                    0,
                    sample_k,
                    vocab,
                )?),
            ));
        } else {
            println!("\n(artifacts lack the `_sampled` family — device backends skipped; re-run `make artifacts`)");
        }
        v
    };
    println!("\n-- decode_loop ({iters} generates per backend) --");
    for (name, mut backend) in backend_list {
        he.engine.reset_stats();
        let tok0 = he.stats.gen_tokens;
        let t0 = std::time::Instant::now();
        for _ in 0..iters {
            std::hint::black_box(he.generate(&flat, backend.as_mut())?);
        }
        let secs = t0.elapsed().as_secs_f64();
        let tokens = he.stats.gen_tokens - tok0;
        assert!(tokens > 0, "decode_loop backend {name} generated zero tokens — dead bench phase");
        let (up, down) = he.engine.bytes_moved();
        let run = BackendRun {
            name,
            tokens,
            secs,
            down,
            up,
            fallbacks: he.engine.fallback_untuples(),
        };
        println!(
            "{:<24} {:>10.1} tokens/s  |  host bytes/token: {} down, {} up{}",
            run.name,
            run.tok_per_sec(),
            fmt_bytes(run.down_per_tok()),
            fmt_bytes(run.up_per_tok()),
            if run.fallbacks > 0 {
                format!("  [{} fused-tuple fallbacks]", run.fallbacks)
            } else {
                String::new()
            },
        );
        runs.push(run);
    }
    // ------------------------------------------------------------------
    // decode_chunk sweep: fused N-step decode through the continuous
    // scheduler with the device counter-RNG categorical backend — each
    // artifact dispatch samples N tokens per live slot on-device, so
    // decode dispatches and host bytes per token must drop ~N×. Gated
    // on the artifact capabilities so older artifact dirs still run the
    // rest of the bench.
    // ------------------------------------------------------------------
    struct ChunkRun {
        n: usize,
        tokens: u64,
        secs: f64,
        down: u64,
        up: u64,
        dispatches: u64,
        waste: u64,
    }
    let mut chunk_runs: Vec<ChunkRun> = Vec::new();
    if he.manifest().has_device_rng() && he.manifest().has_paged_serving() {
        let sizes: Vec<usize> = [1usize, 2, 4, 8]
            .into_iter()
            .filter(|&n| he.manifest().has_decode_chunk(n))
            .collect();
        let n_chunk = if smoke { 2 * bsz } else { 4 * bsz };
        let mut cr = Rng::new(31);
        let chunk_prompts: Vec<Vec<i32>> =
            (0..n_chunk).map(|_| task.sample_prompt(&mut cr).tokens).collect();
        let chunk_budgets = vec![sg; n_chunk];
        he.use_paged_serving(true)?;
        println!("\n-- decode_chunk sweep (device RNG categorical, paged serving, N in {sizes:?}) --");
        for &n in &sizes {
            let stochastic = SamplerConfig { temperature: 0.9, top_p: 0.95, ..Default::default() };
            let mut backend = DeviceCategorical::new(stochastic, sample_k, vocab)?;
            // Warm the serving/chunk executables before timing.
            rollout_continuous_chunked(
                &mut he,
                &chunk_prompts[..bsz],
                &chunk_budgets[..bsz],
                7,
                &mut backend,
                n,
            )?;
            he.engine.reset_stats();
            let r = rollout_continuous_chunked(
                &mut he,
                &chunk_prompts,
                &chunk_budgets,
                7,
                &mut backend,
                n,
            )?;
            assert!(r.useful_tokens > 0, "decode_chunk{n} bench phase generated zero tokens — dead bench phase");
            let (up, down) = he.engine.bytes_moved();
            let sch = r.sched.as_ref().expect("continuous phase carries scheduler stats");
            let dispatches = sch.decode_calls + sch.prefills;
            println!(
                "chunk{:<2} {:>10.1} tokens/s  |  {:.4} dispatches/token  |  host bytes/token: {} down, {} up  |  {} waste tok",
                n,
                r.tok_per_sec(),
                dispatches as f64 / r.useful_tokens as f64,
                fmt_bytes(down as f64 / r.useful_tokens as f64),
                fmt_bytes(up as f64 / r.useful_tokens as f64),
                sch.chunk_waste_tokens,
            );
            chunk_runs.push(ChunkRun {
                n,
                tokens: r.useful_tokens,
                secs: r.secs,
                down,
                up,
                dispatches,
                waste: sch.chunk_waste_tokens,
            });
        }
        he.use_paged_serving(false)?;
    } else {
        println!("\n(artifacts lack the `device_rng` capability — decode_chunk sweep skipped; re-run `make artifacts`)");
    }
    let chunk_json = {
        let mut s = String::new();
        for (i, r) in chunk_runs.iter().enumerate() {
            s.push_str(&format!(
                "{}    \"chunk{}\": {{\n      \"tokens\": {},\n      \"secs\": {:.6},\n      \
                 \"tok_per_sec\": {:.3},\n      \"host_bytes_fetched\": {},\n      \
                 \"host_bytes_uploaded\": {},\n      \
                 \"host_bytes_fetched_per_token\": {:.1},\n      \
                 \"host_bytes_uploaded_per_token\": {:.1},\n      \
                 \"decode_dispatches\": {},\n      \"dispatches_per_token\": {:.4},\n      \
                 \"chunk_waste_tokens\": {}\n    }}",
                if i > 0 { ",\n" } else { "" },
                r.n,
                r.tokens,
                r.secs,
                r.tokens as f64 / r.secs.max(1e-9),
                r.down,
                r.up,
                r.down as f64 / r.tokens.max(1) as f64,
                r.up as f64 / r.tokens.max(1) as f64,
                r.dispatches,
                r.dispatches as f64 / r.tokens.max(1) as f64,
                r.waste,
            ));
        }
        s
    };

    let logits_row_bytes = bsz * vocab * 4;
    let ids_bytes = bsz * 4;
    let topk_bytes = 2 * bsz * sample_k * 4;
    println!(
        "reference: logits row [b,vocab] = {}  |  ids [b] = {}  |  top-k [b,k]x2 = {}  |  KV cache = {}",
        fmt_bytes(logits_row_bytes as f64),
        fmt_bytes(ids_bytes as f64),
        fmt_bytes(topk_bytes as f64),
        fmt_bytes(kv_bytes as f64),
    );

    // JSON: top-level fields mirror the host run (cross-PR continuity);
    // per-backend numbers live under "backends".
    let host = &runs[0];
    let mut backends_json = String::new();
    for (i, r) in runs.iter().enumerate() {
        backends_json.push_str(&format!(
            "{}    \"{}\": {}",
            if i > 0 { ",\n" } else { "" },
            r.name,
            r.json()
        ));
    }
    let json = format!(
        "{{\n  \"bench\": \"decode_loop\",\n  \"schema_version\": 1,\n  \
         \"run\": \"{run_name}\",\n  \"smoke\": {smoke},\n  \
         \"iters\": {iters},\n  \"tokens\": {},\n  \"secs\": {:.6},\n  \
         \"tok_per_sec\": {:.3},\n  \"host_bytes_fetched\": {},\n  \
         \"host_bytes_uploaded\": {},\n  \"host_bytes_fetched_per_token\": {:.1},\n  \
         \"host_bytes_uploaded_per_token\": {:.1},\n  \
         \"logits_row_bytes\": {logits_row_bytes},\n  \"ids_bytes\": {ids_bytes},\n  \
         \"topk_bytes\": {topk_bytes},\n  \"sample_k\": {sample_k},\n  \
         \"kv_cache_bytes\": {kv_bytes},\n  \"fallback_untuples\": {},\n  \
         \"ppo_epoch_uploads\": {{\n    \"epochs\": {epochs},\n    \
         \"legacy_bytes\": {legacy_up},\n    \"staged_bytes\": {staged_up}\n  }},\n  \
         \"backends\": {{\n{backends_json}\n  }},\n  \
         \"chunk_sweep\": {{\n{chunk_json}\n  }}\n}}\n",
        host.tokens,
        host.secs,
        host.tok_per_sec(),
        host.down,
        host.up,
        host.down_per_tok(),
        host.up_per_tok(),
        host.fallbacks,
    );
    std::fs::write("BENCH_decode.json", &json)?;
    println!("wrote BENCH_decode.json");

    // ------------------------------------------------------------------
    // rollout: RLHF experience generation disciplines — the fixed-batch
    // lockstep loop vs the continuous-batching scheduler rollout
    // (`dschat::rollout`) over the same heterogeneous-budget prompt
    // queue. Budgets in [gen_len/4, gen_len] model early-EOS/straggler
    // variance; the lockstep loop cannot honor them (every slot is held
    // until the chunk's slowest row finishes, results truncated), which
    // is exactly the slot-bubble cost being measured. Emits
    // BENCH_rollout.json (tok/s + bubble fraction per discipline).
    // Skipped when artifacts predate serving.
    // ------------------------------------------------------------------
    if !he.manifest().has_serving() {
        println!("(artifacts predate continuous batching — rollout phase skipped; re-run `make artifacts`)");
        return Ok(());
    }
    let n_roll = if smoke { 2 * bsz } else { 6 * bsz };
    let mut rr = Rng::new(23);
    let roll_prompts: Vec<Vec<i32>> =
        (0..n_roll).map(|_| task.sample_prompt(&mut rr).tokens).collect();
    let budgets: Vec<usize> = (0..n_roll)
        .map(|_| rr.range((sg / 4).max(1) as i64, sg as i64 + 1) as usize)
        .collect();
    println!(
        "\n-- rollout ({n_roll} prompts, budgets {}..={} of gen_len {sg}) --",
        budgets.iter().min().unwrap(),
        budgets.iter().max().unwrap()
    );

    // Fixed-batch baseline vs continuous scheduler, both through the
    // shared accounting in examples_support (capacity counts the steps
    // generate actually ran; ablations uses the same helpers).
    let mut sampler = HostFullRow::new(greedy(), 0);
    he.generate(&roll_prompts[..bsz].concat(), &mut sampler)?; // warmup
    let fixed = rollout_fixed_baseline(&mut he, &roll_prompts, &budgets, &mut sampler)?;
    assert!(fixed.useful_tokens > 0, "fixed-batch rollout bench generated zero tokens — dead bench phase");
    println!(
        "fixed_batch              {:>10.1} tokens/s  |  slot bubble {:.1}%  ({} useful tok, {:.3}s)",
        fixed.tok_per_sec(),
        100.0 * fixed.bubble,
        fixed.useful_tokens,
        fixed.secs,
    );

    let mut sampler = HostFullRow::new(greedy(), 0);
    rollout_continuous(&mut he, &roll_prompts[..bsz], &budgets[..bsz], 0, &mut sampler)?; // warmup
    let cont = rollout_continuous(&mut he, &roll_prompts, &budgets, 0, &mut sampler)?;
    assert!(cont.useful_tokens > 0, "continuous rollout bench generated zero tokens — dead bench phase");
    let sch = cont.sched.as_ref().expect("continuous phase carries scheduler stats");
    println!(
        "continuous_scheduler     {:>10.1} tokens/s  |  slot bubble {:.1}%  ({} useful tok, {:.3}s, {} decode calls, {} prefills)",
        cont.tok_per_sec(),
        100.0 * cont.bubble,
        cont.useful_tokens,
        cont.secs,
        sch.decode_calls,
        sch.prefills,
    );

    // Mixed-length rollout: the same continuous discipline over prompts
    // with heterogeneous TRUE lengths (left-padded admission; needs the
    // `padded_prompts` artifact capability) — genuinely mixed experience
    // traffic, with the padded-token overhead reported alongside.
    let cont_mixed = if he.manifest().padded_prompts {
        let mix = mixed_prompts(&task, &mut rr, n_roll, sp / 2);
        let mut sampler = HostFullRow::new(greedy(), 0);
        let r = rollout_continuous(&mut he, &mix, &budgets, 0, &mut sampler)?;
        println!(
            "continuous_mixed_len     {:>10.1} tokens/s  |  slot bubble {:.1}%  pad overhead {:.1}%  ({} useful tok, {:.3}s)",
            r.tok_per_sec(),
            100.0 * r.bubble,
            100.0 * r.pad_overhead,
            r.useful_tokens,
            r.secs,
        );
        Some(r)
    } else {
        println!("(artifacts lack the `padded_prompts` capability — mixed-length rollout skipped)");
        None
    };
    let mixed_json = match &cont_mixed {
        Some(r) => format!(
            "  \"continuous_mixed\": {{\n    \"tok_per_sec\": {:.3},\n    \
             \"useful_tokens\": {},\n    \"secs\": {:.6},\n    \
             \"slot_bubble_fraction\": {:.4},\n    \
             \"pad_overhead_fraction\": {:.4}\n  }},\n",
            r.tok_per_sec(),
            r.useful_tokens,
            r.secs,
            r.bubble,
            r.pad_overhead,
        ),
        None => String::new(),
    };

    let rollout_json = format!(
        "{{\n  \"bench\": \"rollout\",\n  \"schema_version\": 1,\n  \
         \"run\": \"{run_name}\",\n  \"smoke\": {smoke},\n  \
         \"n_prompts\": {n_roll},\n  \"group\": {bsz},\n  \"gen_len\": {sg},\n  \
         \"fixed\": {{\n    \"tok_per_sec\": {:.3},\n    \"useful_tokens\": {},\n    \
         \"secs\": {:.6},\n    \"slot_bubble_fraction\": {:.4}\n  }},\n  \
         \"continuous\": {{\n    \"tok_per_sec\": {:.3},\n    \"useful_tokens\": {},\n    \
         \"secs\": {:.6},\n    \"slot_bubble_fraction\": {:.4},\n    \
         \"decode_calls\": {},\n    \"prefills\": {},\n    \"retired_eos\": {},\n    \
         \"retired_length\": {}\n  }},\n{mixed_json}  \
         \"speedup_tok_per_sec\": {:.3},\n  \"bubble_reduction\": {:.4}\n}}\n",
        fixed.tok_per_sec(),
        fixed.useful_tokens,
        fixed.secs,
        fixed.bubble,
        cont.tok_per_sec(),
        cont.useful_tokens,
        cont.secs,
        cont.bubble,
        sch.decode_calls,
        sch.prefills,
        sch.retired_eos,
        sch.retired_length,
        cont.tok_per_sec() / fixed.tok_per_sec().max(1e-9),
        fixed.bubble - cont.bubble,
    );
    std::fs::write("BENCH_rollout.json", &rollout_json)?;
    println!("wrote BENCH_rollout.json");
    Ok(())
}
