//! Bench: L3 hot paths that must never bottleneck the artifacts — samplers,
//! GAE/reward shaping, data synthesis, partition planning, JSON parsing.
//! `cargo bench --bench hot_paths`.

use dschat::coordinator::gae;
use dschat::data::synthetic::TaskGen;
use dschat::data::{Blend, DataSplit};
use dschat::sampling::{DeviceTopK, RowRef, Sampler, SamplerConfig, SamplingBackend};
use dschat::util::bench::Bench;
use dschat::util::json::Json;
use dschat::util::rng::Rng;
use dschat::zero::partition;

fn main() {
    println!("== L3 hot paths ==");
    let b = Bench::default();
    let mut rng = Rng::new(0);

    // Sampler over a realistic logits row (vocab 512, top-k+top-p on).
    let logits: Vec<f32> = (0..512).map(|_| rng.normal() as f32 * 3.0).collect();
    let history: Vec<i32> = (0..64).map(|_| rng.below(512) as i32).collect();
    let mut sampler = Sampler::new(
        SamplerConfig {
            temperature: 0.9,
            top_k: 50,
            top_p: 0.95,
            repetition_penalty: 1.1,
            ..Default::default()
        },
        1,
    );
    b.run("sampler_topk_topp_v512", || {
        std::hint::black_box(sampler.sample(&logits, &history));
    })
    .print(Some((1.0, "tokens")));

    // DeviceTopK host finish over a k=32 candidate row — the O(k) work
    // that replaces the full-row pass when sampling runs on device.
    let mut cand: Vec<(f32, i32)> =
        (0..32).map(|_| (rng.normal() as f32 * 3.0, rng.below(512) as i32)).collect();
    cand.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    let cand_vals: Vec<f32> = cand.iter().map(|c| c.0).collect();
    let cand_ids: Vec<i32> = cand.iter().map(|c| c.1).collect();
    let mut device = DeviceTopK::new(
        SamplerConfig { temperature: 0.9, top_p: 0.95, ..Default::default() },
        1,
        32,
        512,
    )
    .unwrap();
    b.run("device_topk_host_finish_k32", || {
        std::hint::black_box(
            device
                .sample(RowRef::TopK { vals: &cand_vals, ids: &cand_ids }, &history)
                .unwrap(),
        );
    })
    .print(Some((1.0, "tokens")));

    // GAE + whiten over a [64, 511] batch (a `medium`-scale PPO batch).
    let n = 511;
    let rewards: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
    let values: Vec<f32> = (0..=n).map(|_| rng.normal() as f32).collect();
    b.run("gae_seq511", || {
        std::hint::black_box(gae::gae(&rewards, &values, 1.0, 0.95));
    })
    .print(Some((n as f64, "tokens")));

    let mut adv: Vec<f32> = (0..64 * n).map(|_| rng.normal() as f32).collect();
    let mask = vec![1.0f32; 64 * n];
    b.run("whiten_64x511", || {
        let mut a = adv.clone();
        gae::whiten(&mut a, &mask);
        std::hint::black_box(a);
    })
    .print(Some((64.0 * n as f64, "tokens")));
    adv.truncate(0);

    // Data synthesis: a full SFT batch (b=64) through the blend machinery.
    let task = TaskGen::new(512, 64, 64);
    let mut blend = Blend::new(vec![(task, 1.0)], DataSplit::new(2.0, 4.0, 4.0));
    b.run("blend_sft_batch_b64_s128", || {
        std::hint::black_box(blend.sft_batch(&mut rng, 64));
    })
    .print(Some((64.0, "seqs")));

    // ZeRO partition planning at 175B/1024-way.
    b.run("zero_partition_175b_1024way", || {
        std::hint::black_box(partition(175_000_000_000usize / 4, 1024));
    })
    .print(None);

    // Manifest-scale JSON parse.
    let manifest = std::fs::read_to_string("artifacts/tiny/manifest.json").ok();
    if let Some(text) = manifest {
        b.run("json_parse_manifest", || {
            std::hint::black_box(Json::parse(&text).unwrap());
        })
        .print(Some((text.len() as f64, "bytes")));
    }
}
