//! Bench: regenerate each paper figure's data series — one bench per figure
//! (3, 4, 5, 6, 7). `cargo bench --bench paper_figures`.

use dschat::report;
use dschat::util::bench::Bench;

fn main() {
    println!("== paper figures (simulator) ==");
    let b = Bench::quick();
    b.run("figure3_single_gpu_throughput", || {
        assert!(!report::figure3().rows.is_empty());
    })
    .print(None);
    b.run("figure4_node_throughput", || {
        assert!(!report::figure4().rows.is_empty());
    })
    .print(None);
    b.run("figure5_phase_breakdown", || {
        assert!(!report::figure5().rows.is_empty());
    })
    .print(None);
    b.run("figure6_effective_tflops", || {
        assert!(!report::figure6().rows.is_empty());
    })
    .print(None);
    b.run("figure7_scalability", || {
        assert_eq!(report::figure7().len(), 2);
    })
    .print(None);

    println!("\n-- regenerated output --\n");
    for t in report::all_figures() {
        t.print();
    }
}
