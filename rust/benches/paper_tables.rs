//! Bench: regenerate each paper table and time the simulator paths — one
//! bench per table (Tables 1, 2, 3, 4–6). `cargo bench --bench paper_tables`.

use dschat::report;
use dschat::util::bench::Bench;

fn main() {
    println!("== paper tables (simulator) ==");
    let b = Bench::quick();
    b.run("table1_single_node", || {
        let t = report::table1();
        assert!(!t.rows.is_empty());
    })
    .print(None);
    b.run("table2_multi_node", || {
        let t = report::table2();
        assert!(!t.rows.is_empty());
    })
    .print(None);
    b.run("table3_max_model", || {
        let t = report::table3();
        assert_eq!(t.rows.len(), 1);
    })
    .print(None);
    b.run("tables456_breakdowns", || {
        let ts = report::tables456();
        assert_eq!(ts.len(), 3);
    })
    .print(None);

    println!("\n-- regenerated output --\n");
    for t in report::all_tables() {
        t.print();
    }
}
